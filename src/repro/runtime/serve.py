"""Serving loops: slot-based continuous batching (the fast path) and the
legacy windowed loop (kept as a measured baseline).

Two servers share one request API (``submit`` / ``step`` / ``flush`` /
``done``), so the serving facade's
:class:`~repro.serving.executor.EngineExecutor` drives either:

* :class:`ContinuousBatchingEngine` — a fixed set of *slots* over a
  shared paged KV pool (``runtime/paging.py``).  A request is admitted
  into any free slot the moment enough KV blocks exist (its whole
  ``prompt_len + max_new`` budget is reserved up front, so a running
  request can never strand mid-decode); it decodes for *exactly* its
  own ``max_new`` steps; the step it finishes, its blocks free and its
  slot is re-admittable — decode proceeds continuously while slots
  churn.  Admission that would overcommit the pool raises
  :class:`~repro.runtime.paging.OutOfBlocksError` internally; the
  request waits in the queue and the deferral is counted (the facade
  surfaces it as backpressure telemetry).  Attention runs the Pallas
  paged-decode kernel (``kernels/paged_attention.py``): the block table
  is walked in-kernel, so per-step HBM traffic is O(blocks touched),
  not O(batch * max_len) gather.  Sampling (greedy by default, or
  per-request temperature/top-k/seed via
  :class:`~repro.runtime.sampling.SamplingParams`) happens *inside* the
  fused decode program — one dispatch per step, ``[B]`` ints on the
  wire.

* :class:`WindowedBaselineServer` — the original *windowed* loop: a
  bounded window of requests prefills together, then every request
  decodes for ``max(max_new)`` steps.  Finished requests keep burning
  decode steps as padding, and newly-arrived requests wait for the
  whole window to drain.  Kept only as the baseline that
  ``benchmarks/decode_bench.py`` and ``benchmarks/router_bench.py``
  measure the continuous engine against.

``BatchingServer`` — the windowed loop's old public name — is now a
deprecated shim: it warns and forwards construction to the engine
(falling back to the windowed loop only for stacks paged decode cannot
serve).  New code should not call either constructor directly; build a
:class:`~repro.serving.FleetSpec` and serve through
:class:`~repro.serving.ServingClient` instead.

Shapes stay bucket-fixed in both servers (``max_batch`` / ``max_slots``
and ``prompt_len``), so every step hits a pre-compiled program — no
compile stalls in the serving path.

Two granularities of progress:
  * ``flush()`` — blocking: run until at least one request completes.
  * ``step()``  — non-blocking building block: advance by ONE unit of
    work and return immediately.  This is what lets several servers —
    the router's accelerator pools — interleave on one host instead of
    each monopolizing it for a full generation.
"""
from __future__ import annotations

import struct
import time
import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.partition import PartitionPlan
from repro.models import transformer as T
from repro.runtime import paging
from repro.runtime.paging import BlockAllocator, OutOfBlocksError
from repro.runtime.sampling import GREEDY, SamplingParams, sample_logits


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [S] int32
    max_new: int = 8
    sampling: Optional[SamplingParams] = None   # None -> greedy
    output: Optional[np.ndarray] = None


@dataclass
class _ActiveWindow:
    """One in-progress bounded window (prefill done, decode underway)."""
    batch: List[Request]
    cache: object
    last: object                       # [b, 1] last sampled token
    gen: List[np.ndarray]
    remaining: int                     # decode steps left
    steps_done: int = 0                # decode steps taken so far


class WindowedBaselineServer:
    """The legacy windowed batching loop (greedy-only).  Baseline for the
    decode benchmarks; serve through ``repro.serving`` instead."""

    def __init__(self, params, cfg: ModelConfig,
                 plan: Optional[PartitionPlan] = None, tp: int = 1,
                 max_batch: int = 8, prompt_len: int = 32,
                 max_len: int = 64):
        self.params, self.cfg, self.plan, self.tp = params, cfg, plan, tp
        self.max_batch, self.prompt_len, self.max_len = (max_batch,
                                                         prompt_len, max_len)
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._active: Optional[_ActiveWindow] = None
        self._prefill = jax.jit(
            lambda p, toks, cache: T.prefill(p, cfg, toks, cache, plan, tp))
        self._decode = jax.jit(
            lambda p, tok, cache: T.decode_step(p, cfg, tok, cache, plan, tp))
        self.reset_stats()

    def reset_stats(self) -> None:
        self.total_tokens = 0             # real sampled tokens only
        self.decode_steps = 0
        self.decode_tokens = 0            # tokens produced by decode steps
        self.decode_s = 0.0               # wall time inside decode steps
        self.prefill_tokens = 0           # prompt tokens prefilled
        self.deferrals = 0                # windowed loop never defers

    def submit(self, req: Request) -> None:
        _require_prompt(req, "server")
        assert req.prompt.shape[0] <= self.prompt_len
        assert self.prompt_len + req.max_new <= self.max_len, \
            (req.rid, req.max_new, self.max_len)
        if req.sampling is not None and not req.sampling.greedy:
            warnings.warn(
                f"request {req.rid}: the windowed baseline decodes "
                f"greedily and ignores SamplingParams; use an engine-"
                f"backed pool for non-greedy sampling")
        self.queue.append(req)

    @property
    def pending(self) -> int:
        """Requests admitted but not yet completed (queued + in-window)."""
        return len(self.queue) + (len(self._active.batch)
                                  if self._active else 0)

    @property
    def occupancy(self) -> float:
        """Fraction of batch slots doing useful work right now."""
        if self._active is None:
            return 0.0
        return len(self._active.batch) / self.max_batch

    def step(self) -> List[Request]:
        """Advance by one unit of work and return requests it completed.

        No active window: start one (prefill + first token) from the queue.
        Active window: run one decode step.  Returns [] until the window's
        last decode step, at which point the whole batch is finalized.
        """
        if self._active is None:
            if not self.queue:
                return []
            self._start_window()
        else:
            w = self._active
            t0 = time.perf_counter()
            out = self._decode(self.params, w.last.astype(jnp.int32), w.cache)
            w.cache = out.cache
            w.last = jnp.argmax(out.logits[:, -1], axis=-1)[:, None]
            w.gen.append(np.asarray(w.last))
            self.decode_s += time.perf_counter() - t0
            w.remaining -= 1
            w.steps_done += 1
            self.decode_steps += 1
            # padding rows past a request's own max_new are not tokens
            useful = sum(1 for r in w.batch if w.steps_done <= r.max_new - 1)
            self.decode_tokens += useful
            self.total_tokens += useful
        return self._finish_if_done()

    def flush(self) -> List[Request]:
        """Serve one bounded window to completion (blocking form of step)."""
        if self._active is None and not self.queue:
            return []
        while True:
            batch = self.step()
            if batch:
                return batch

    def stats(self) -> Dict[str, float]:
        return {"total_tokens": self.total_tokens,
                "decode_steps": self.decode_steps,
                "decode_tokens": self.decode_tokens,
                "decode_s": self.decode_s,
                "prefill_tokens": self.prefill_tokens,
                "deferrals": self.deferrals}

    def _start_window(self) -> None:
        batch = self.queue[:self.max_batch]
        self.queue = self.queue[self.max_batch:]
        b = self.max_batch                        # fixed bucket: no recompiles
        toks = np.zeros((b, self.prompt_len), np.int32)
        for i, r in enumerate(batch):
            toks[i, -r.prompt.shape[0]:] = r.prompt   # left-pad
        cache = T.init_cache(self.cfg, b, self.max_len, self.tp)
        out = self._prefill(self.params, jnp.asarray(toks), cache)
        last = jnp.argmax(out.logits[:, -1], axis=-1)[:, None]
        max_new = max(r.max_new for r in batch)
        self.prefill_tokens += self.prompt_len * len(batch)
        self.total_tokens += sum(1 for r in batch if r.max_new >= 1)
        self._active = _ActiveWindow(batch, out.cache, last,
                                     [np.asarray(last)], max_new - 1)

    def _finish_if_done(self) -> List[Request]:
        w = self._active
        if w is None or w.remaining > 0:
            return []
        gen = np.concatenate(w.gen, axis=1)       # [b, max_new]
        for i, r in enumerate(w.batch):
            r.output = gen[i, :r.max_new]
            self.done[r.rid] = r
        self._active = None
        return w.batch


def engine_or_windowed(params, cfg: ModelConfig,
                       plan: Optional[PartitionPlan] = None, tp: int = 1,
                       max_slots: int = 8, prompt_len: int = 32,
                       max_len: int = 64, block_size: int = 8,
                       num_blocks: Optional[int] = None,
                       prefill_chunk: Optional[int] = None,
                       harden: bool = False, watchdog_steps: int = 8,
                       scrub_blocks: int = 2,
                       on_fallback=None):
    """The one engine-with-windowed-fallback policy.

    Constructs a :class:`ContinuousBatchingEngine`; stacks paged decode
    cannot serve (hybrid/SSM mixers, sliding windows, int8 KV — the
    engine raises ``ValueError``) fall back to the windowed loop, after
    calling ``on_fallback(exc)`` if given.  Both the serving facade's
    ``make_server`` and the deprecated :func:`BatchingServer` shim come
    through here, so the fallback conditions live in exactly one place.
    """
    if max_len > prompt_len:
        try:
            return ContinuousBatchingEngine(
                params, cfg, plan=plan, tp=tp, max_slots=max_slots,
                prompt_len=prompt_len, max_len=max_len,
                block_size=block_size, num_blocks=num_blocks,
                prefill_chunk=prefill_chunk, harden=harden,
                watchdog_steps=watchdog_steps, scrub_blocks=scrub_blocks)
        except ValueError as e:    # non-pageable: keep the windowed loop
            if on_fallback is not None:
                on_fallback(e)
    return WindowedBaselineServer(params, cfg, plan=plan, tp=tp,
                                  max_batch=max_slots,
                                  prompt_len=prompt_len, max_len=max_len)


def BatchingServer(params, cfg: ModelConfig,
                   plan: Optional[PartitionPlan] = None, tp: int = 1,
                   max_batch: int = 8, prompt_len: int = 32,
                   max_len: int = 64):
    """Deprecated windowed-server entry point.

    Warns and forwards to :class:`ContinuousBatchingEngine` (same
    submit/step/flush/done API, strictly better scheduling), falling
    back to the windowed loop via :func:`engine_or_windowed`.  New code
    should build a :class:`repro.serving.FleetSpec` and go through
    :class:`repro.serving.ServingClient` instead.
    """
    warnings.warn(
        "BatchingServer is deprecated; serve through repro.serving "
        "(FleetSpec -> ServingClient). Forwarding to the continuous-"
        "batching engine.", DeprecationWarning, stacklevel=2)
    return engine_or_windowed(params, cfg, plan=plan, tp=tp,
                              max_slots=max_batch, prompt_len=prompt_len,
                              max_len=max_len)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------
@dataclass
class _Slot:
    """One occupied decode slot."""
    req: Request
    gen: List[int]                     # sampled tokens so far
    remaining: int                     # decode steps left (exact)
    sampled: bool = False              # non-greedy sampling requested


_DEFER = object()        # admission verdict: blocks unavailable, retry later


def _require_prompt(req: Request, who: str) -> None:
    """Every server rejects empty prompts up front: a zero-length prompt
    used to slip into a batch and crash it mid-admission (the -0 slice
    selects the whole row)."""
    if req.prompt.shape[0] == 0:
        raise ValueError(
            f"request {req.rid}: empty prompt — the {who} needs at "
            f"least one prompt token to prefill")


@jax.jit
def _gather_block_rows(caches, rows):
    """Export the KV content of ``rows`` from every sublayer pool —
    one fused device call per handoff (the DPU->VPU DMA analogue)."""
    return {key: (st.k_pool[:, rows], st.v_pool[:, rows])
            for key, st in caches.items()}


@jax.jit
def _paste_block_rows(caches, kv, rows):
    """Import handed-off KV content into ``rows`` of every sublayer
    pool (mirrored geometry); the receiving side of the handoff."""
    out = {}
    for key, st in caches.items():
        k_b, v_b = kv[key]
        out[key] = st._replace(
            k_pool=st.k_pool.at[:, rows].set(k_b.astype(st.k_pool.dtype)),
            v_pool=st.v_pool.at[:, rows].set(v_b.astype(st.v_pool.dtype)))
    return out


class ContinuousBatchingEngine:
    """Slot-based continuous-batching decode over a paged KV pool.

    ``max_slots`` batch slots share a pool of ``num_blocks`` KV blocks
    of ``block_size`` tokens.  Requests admit into free slots as soon as
    the pool can cover their full ``prompt_len + max_new`` budget (the
    reservation is up-front, so admitted work never deadlocks on
    blocks), decode for exactly their own ``max_new`` steps, and free
    their slot + blocks the step they finish.  One ``step()`` =
    admissions (each a batch-1 prefill pasted into the pool) + one
    batched decode step for every occupied slot.

    Sampling is per-request (``Request.sampling``): greedy when unset,
    otherwise temperature/top-k with a counter-based key
    (``fold_in(seed, token_index)``) so outputs are independent of batch
    composition.  Both the admission prefill and the decode step sample
    inside their fused jitted programs.

    Per-token observability: set ``on_token`` to a callable
    ``(rid, token)``; it fires the step each token is sampled (admission
    first-tokens included) — this is what feeds the serving facade's
    ``ResponseHandle.stream()``.

    The engine keeps the block table and per-slot lengths as host-side
    numpy mirrors (the allocator is host code) and pushes them into the
    per-layer :class:`~repro.runtime.paging.PagedKVState` before each
    device call; device-side length bumps from ``append_tokens`` are
    mirrored by the host bookkeeping, so the push is idempotent.
    """

    def __init__(self, params, cfg: ModelConfig,
                 plan: Optional[PartitionPlan] = None, tp: int = 1,
                 max_slots: int = 8, prompt_len: int = 32,
                 max_len: int = 64, block_size: int = 8,
                 num_blocks: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 harden: bool = False, watchdog_steps: int = 8,
                 scrub_blocks: int = 2):
        self.params, self.cfg, self.plan, self.tp = params, cfg, plan, tp
        self.max_slots, self.prompt_len = max_slots, prompt_len
        self.max_len, self.block_size = max_len, block_size
        assert max_len > prompt_len, (max_len, prompt_len)
        # chunked paged prefill: prompts longer than the prompt_len
        # bucket admit in block-aligned chunks of this many tokens,
        # written straight into paged blocks (no dense scratch cache
        # bounds them) — the only remaining prompt limit is max_len
        self.prefill_chunk = (prefill_chunk if prefill_chunk is not None
                              else max(block_size,
                                       prompt_len // block_size
                                       * block_size))
        assert self.prefill_chunk % block_size == 0, \
            (self.prefill_chunk, block_size)
        self.table_width = -(-max_len // block_size)
        if num_blocks is None:
            num_blocks = max_slots * self.table_width
        assert num_blocks >= self.table_width, \
            "pool smaller than one max-length request"
        self.alloc = BlockAllocator(num_blocks)
        self.shared = paging.SharedBlockIndex(self.alloc)
        self.table = -np.ones((max_slots, self.table_width), np.int32)
        self.lengths = np.zeros(max_slots, np.int32)
        self.caches = T.init_paged_decode_cache(
            cfg, max_slots, num_blocks, block_size, tp,
            max_blocks=self.table_width)
        self.slots: List[Optional[_Slot]] = [None] * max_slots
        self.last = np.zeros((max_slots, 1), np.int32)
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._dirty = True                    # host table/lengths changed
        # --- radiation hardening (SEU detection + recovery) -----------
        # harden=True turns on per-block integrity digests: sealed (full)
        # blocks are checksummed, the decode step recomputes every live
        # row's checksum *inside the fused program* (detection lands the
        # same step a corrupted block is read, before any token escapes),
        # and scrub() gives idle pools a budgeted background pass.  The
        # token path itself is untouched — hardened outputs with no
        # faults are bit-identical to hardening-off.
        self.harden = bool(harden)
        self.watchdog_steps = int(watchdog_steps)
        self.scrub_blocks = int(scrub_blocks)
        self.digests = paging.BlockDigestStore()
        # whoever frees a block (finalize, shared-index refcount drop,
        # eviction) retires its seal with it — a recycled block can never
        # false-positive against stale content
        self.alloc.on_release = self.digests.forget
        self.stalled: set = set()       # slots latched by a stall fault
        self._tripped: set = set()      # stalled slots already evicted
        self._stall_age = np.zeros(max_slots, np.int64)
        self._restore_queue: List[tuple] = []   # (req, gen) to replay
        self._armed_flips: List[int] = []       # kv_bitflip seeds pending
        self.mute_rids: set = set()     # one-shot emission suppression
        self._idle_steps = 0            # livelock guard
        # a disaggregated decode engine must not self-restore: its KV
        # came from a peer prefill engine under a different plan, so the
        # seam owner re-runs the handoff instead (restore_import)
        self.external_restore = False
        self.on_token: Optional[Callable[[int, int], None]] = None
        # flight-recorder hook: ``on_stage(stage, t0, t1, rids, attrs)``
        # with wall perf_counter endpoints; installed by EngineExecutor
        # only while a traced batch runs, so the normal hot path pays a
        # single None check per device call
        self.on_stage = None
        # per-slot sampling knobs, threaded through the jit boundary.
        # Device copies are refreshed per admission round (the only
        # place they change) and the per-token step counters only when
        # a sampled request is active, so the greedy decode hot path
        # pays no per-step transfers.
        self._temps = np.zeros(max_slots, np.float32)
        self._topks = np.zeros(max_slots, np.int32)
        self._seeds = np.zeros(max_slots, np.int32)
        self._gen_counts = np.zeros(max_slots, np.int32)  # tokens so far
        self._knobs_dev = (jnp.asarray(self._temps),
                           jnp.asarray(self._topks),
                           jnp.asarray(self._seeds))
        self.reset_stats()
        # admissions prefill together at the max_slots bucket (rows for
        # non-admitted slots are dead weight but keep shapes fixed)
        self._prefill_cache = T.init_cache(cfg, max_slots, prompt_len, tp)
        # two compiled variants per program: a pure-argmax one (identical
        # to the pre-sampling program — an all-greedy batch pays zero
        # sampling overhead, which matters on tiny configs where the
        # PRNG work rivals the forward pass) and a sampling one; the
        # host picks per call based on the live slots
        self._admit_step = jax.jit(self._admit_impl, static_argnums=(8,))
        self._chunk_step = jax.jit(self._chunk_impl, static_argnums=(8,))

        def _decode_greedy(p, toks, caches):
            out = T.decode_step(p, cfg, toks, caches, plan, tp)
            # greedy sampling inside the program: one dispatch per step,
            # [B] ints on the wire instead of [B, V] logits
            return jnp.argmax(out.logits[:, -1], axis=-1), out.cache
        self._decode = jax.jit(_decode_greedy)

        def _decode_sampled(p, toks, caches, temps, topks, seeds, steps):
            out = T.decode_step(p, cfg, toks, caches, plan, tp)
            nxt = sample_logits(out.logits[:, -1], temps, topks, seeds,
                                steps)
            return nxt, out.cache
        self._decode_with = jax.jit(_decode_sampled)

        # hardened variants: the same decode, plus per-block integrity
        # checksums of the *whole* pool fused into the dispatch — a
        # straight [NB+1] reduction with no row-index operand, so XLA
        # reads memory it already touches instead of materializing a
        # gather (the gathered variant cost ~30% of decode throughput
        # on small configs).  Checksums are computed *after* the step's
        # KV append, so a just-filled tail block's value doubles as its
        # seal — sealing on the decode hot path costs no extra device
        # call.  The host compares only the blocks it holds seals for.

        def _cache_checksums(caches):
            total = None
            for key in sorted(caches):
                s = paging.pool_checksums(caches[key])
                total = s if total is None else total + s
            return total
        self._checksum = jax.jit(_cache_checksums)

        def _decode_greedy_h(p, toks, caches):
            nxt, caches = _decode_greedy(p, toks, caches)
            return nxt, caches, _cache_checksums(caches)
        self._decode_h = jax.jit(_decode_greedy_h)

        def _decode_sampled_h(p, toks, caches, temps, topks, seeds, steps):
            nxt, caches = _decode_sampled(p, toks, caches, temps, topks,
                                          seeds, steps)
            return nxt, caches, _cache_checksums(caches)
        self._decode_with_h = jax.jit(_decode_sampled_h)

    def reset_stats(self) -> None:
        """Zero the telemetry counters (post-jit-warmup)."""
        self.total_tokens = 0                 # real sampled tokens only
        self.decode_steps = 0
        self.occupancy_sum = 0.0
        self.decode_tokens = 0                # tokens from decode steps only
        self.decode_s = 0.0                   # wall time in decode steps
        self.admit_s = 0.0                    # wall time in admission steps
        self.prefill_tokens = 0               # prompt tokens prefilled
        self.deferrals = 0                    # OutOfBlocks admission deferrals
        self.shared.hits = 0                  # prefix blocks served by index
        self.shared.lookups = 0               # share attempts (hits + misses)
        self.bitflips_detected = 0            # checksum mismatches caught
        self.blocks_quarantined = 0           # blocks pulled from service
        self.watchdog_trips = 0               # stalled slots evicted
        self.replays = 0                      # evicted requests rebuilt
        self.scrubbed_blocks = 0              # blocks verified by scrub()

    # ------------------------------------------------------------------
    # public API (shared with WindowedBaselineServer)
    # ------------------------------------------------------------------
    def padded_prompt_len(self, s: int) -> int:
        """Prompt context a length-``s`` prompt occupies once admitted:
        the ``prompt_len`` bucket when it fits (left-padded, matching
        the windowed baseline), else the next prefill-chunk multiple."""
        if s <= self.prompt_len:
            return self.prompt_len
        c = self.prefill_chunk
        return -(-s // c) * c

    def submit(self, req: Request) -> None:
        _require_prompt(req, "engine")
        padded = self.padded_prompt_len(int(req.prompt.shape[0]))
        assert padded + req.max_new <= self.max_len, \
            (req.rid, req.prompt.shape[0], req.max_new, self.max_len)
        self.queue.append(req)

    def _held_blocks(self) -> List[int]:
        """Per-slot count of blocks currently owned — the baseline
        ``plan_blocks`` needs: growing one row must restate every other
        row's holdings so nothing it already owns is re-planned."""
        return [int((self.table[j] >= 0).sum())
                for j in range(self.max_slots)]

    @property
    def pending(self) -> int:
        """Requests admitted but not yet completed (queued + in-slot +
        evicted-awaiting-replay)."""
        return (len(self.queue) + sum(s is not None for s in self.slots)
                + len(self._restore_queue))

    @property
    def occupancy(self) -> float:
        """Fraction of decode slots doing useful work right now."""
        return sum(s is not None for s in self.slots) / self.max_slots

    @property
    def prefix_hits(self) -> int:
        """Prompt blocks served from the content-hash index."""
        return self.shared.hits

    @property
    def prefix_lookups(self) -> int:
        """Prompt blocks offered to the index (hits + misses)."""
        return self.shared.lookups

    def step(self) -> List[Request]:
        """Admit into free slots, then run one decode step; returns the
        requests completed by either (admission completes ``max_new==1``
        requests outright — their single token comes from prefill).

        Hardening rides the same cadence: armed bit flips land first
        (so detection sees them the very step their block is next
        read), the watchdog ages stalled slots, and evicted requests
        replay into free slots before new admissions (recovery has
        priority over fresh work)."""
        if self._armed_flips:
            self._apply_armed_flips()
        if self.stalled:
            self._watchdog()
        if self._restore_queue and not self.external_restore:
            self._restore_pending()
        completed = self._admit()
        completed += self._decode_once()
        # livelock guard: a permanently-stalled single slot (or a
        # restore that can never fit) must fail loudly, not spin the
        # drive loop forever
        if (completed or any(s is not None for s in self.slots)
                or not (self.queue or self._restore_queue)):
            self._idle_steps = 0
        else:
            self._idle_steps += 1
            if self._idle_steps > max(1000, 10 * self.watchdog_steps):
                raise RuntimeError(
                    f"engine livelock: {len(self.queue)} queued + "
                    f"{len(self._restore_queue)} awaiting replay, but no "
                    f"slot can make progress (stalled={sorted(self.stalled)},"
                    f" quarantined={len(self.alloc.quarantined)} blocks)")
        return completed

    def flush(self) -> List[Request]:
        """Blocking form: run until at least one request completes."""
        if not self.pending:
            return []
        while True:
            done = self.step()
            if done:
                return done

    def stats(self) -> Dict[str, float]:
        steps = max(self.decode_steps, 1)
        return {"total_tokens": self.total_tokens,
                "decode_steps": self.decode_steps,
                "mean_occupancy": self.occupancy_sum / steps,
                "decode_tokens": self.decode_tokens,
                "decode_s": self.decode_s,
                "admit_s": self.admit_s,
                "prefill_tokens": self.prefill_tokens,
                "shared_block_hits": self.shared.hits,
                "shared_block_lookups": self.shared.lookups,
                "deferrals": self.deferrals,
                "bitflips_detected": self.bitflips_detected,
                "blocks_quarantined": self.blocks_quarantined,
                "watchdog_trips": self.watchdog_trips,
                "replays": self.replays,
                "scrubbed_blocks": self.scrubbed_blocks}

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    def _admit_impl(self, params, toks, prefill_cache, caches, admit,
                    temps, topks, seeds, sampled):
        """One fused device call per admission round: bucket-shaped
        prefill, paste of every admitted row's KV into its paged blocks
        (non-admitted rows scatter to the trash row), and the first
        sampled token per row (token index 0 for the sampling key;
        ``sampled`` is static — all-greedy rounds compile to argmax)."""
        out = T.prefill(params, self.cfg, toks, prefill_cache,
                        self.plan, self.tp)
        new_caches = {}
        for key, st in caches.items():
            dc = out.cache[key]
            new_caches[key] = jax.vmap(
                paging.write_prefill_batch,
                in_axes=(0, 0, 0, None))(st, dc.k, dc.v, admit)
        logits = out.logits[:, -1]
        if sampled:
            firsts = sample_logits(logits, temps, topks, seeds,
                                   jnp.zeros_like(seeds))
        else:
            firsts = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return firsts, new_caches

    def _chunk_impl(self, params, toks, caches, seq, start,
                    temps, topks, seeds, sampled):
        """One chunked-prefill device call: run chunk ``toks`` [1, C] of
        sequence ``seq`` at absolute positions ``start..start+C-1``,
        pasting its KV straight into the sequence's paged blocks
        (``write_prefill_chunk``) and attending against the paged prefix
        written by earlier chunks.  Returns the sampled/argmax token off
        the chunk's last logit — callers keep only the final chunk's
        (token index 0 for the sampling key, matching the fused bucket
        admission) — plus the updated caches."""
        out = T.prefill_paged_chunk(params, self.cfg, toks, caches, seq,
                                    start, self.plan, self.tp)
        logits = out.logits[:, -1]
        if sampled:
            firsts = sample_logits(logits, temps, topks, seeds,
                                   jnp.zeros_like(seeds))
        else:
            firsts = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return firsts, out.cache

    def _push_tables(self, table: Optional[np.ndarray] = None,
                     lengths: Optional[np.ndarray] = None) -> None:
        """Broadcast the host table/length mirrors into every sublayer
        cache.  ``table``/``lengths`` override the mirrors for one push
        — the replay path masks every row but the one being rebuilt, so
        the fixed-shape decode program touches nothing else; the next
        dirty push restores the true mirrors."""
        tbl = jnp.asarray(self.table if table is None else table)
        lens = jnp.asarray(self.lengths if lengths is None else lengths)

        def fix(st: paging.PagedKVState) -> paging.PagedKVState:
            return st._replace(
                block_table=jnp.broadcast_to(tbl, st.block_table.shape),
                lengths=jnp.broadcast_to(lens, st.lengths.shape))
        self.caches = jax.tree_util.tree_map(
            fix, self.caches,
            is_leaf=lambda s: isinstance(s, paging.PagedKVState))

    def _emit(self, rid: int, tok: int) -> None:
        if self.on_token is not None:
            self.on_token(rid, tok)

    def _stage(self, stage: str, t0: float, t1: float, rids, **attrs):
        if self.on_stage is not None:
            self.on_stage(stage, t0, t1, list(rids), attrs)

    # ------------------------------------------------------------------
    # radiation hardening: injection, detection, recovery
    # ------------------------------------------------------------------
    def arm_bitflip(self, seed: int = 0) -> None:
        """Arm one SEU: at the next step with sealed live KV, flip one
        ``seed``-chosen bit in a live paged block.  Armed (not applied
        immediately) because batches run wall-synchronously between
        virtual ticks — the upset must land while KV is actually live,
        exactly when a real particle strike would matter."""
        self._armed_flips.append(int(seed))

    def stall_slot(self, slot: int) -> None:
        """Latch a slot-stall fault: the next request decoding in this
        slot stops making progress (the scheduler cannot see the latent
        upset, so admission still uses the slot) until the watchdog
        evicts it; after the trip the slot is quarantined from admission
        until :meth:`unstall_slot`."""
        self.stalled.add(int(slot) % self.max_slots)

    def unstall_slot(self, slot: int) -> None:
        i = int(slot) % self.max_slots
        self.stalled.discard(i)
        self._tripped.discard(i)
        self._stall_age[i] = 0

    def scrub(self, budget: Optional[int] = None) -> int:
        """Budgeted background integrity pass: verify up to ``budget``
        sealed blocks (round-robin) against their digests; corrupted
        blocks quarantine and their requests replay.  Costs nothing when
        no blocks are sealed; the decode hot path carries its own fused
        full verify, so this mainly covers blocks held while a pool sits
        idle between batches.  Returns blocks verified."""
        if not self.harden or len(self.digests) == 0:
            return 0
        blocks = self.digests.scrub_batch(
            self.scrub_blocks if budget is None else budget)
        if not blocks:
            return 0
        sums = self._row_checksums(blocks)
        self.scrubbed_blocks += len(blocks)
        bad_slots: set = set()
        for b, s in zip(blocks, sums):
            if self.digests.get(b) != int(s):
                bad_slots |= self._on_corrupt_block(b)
        for i in sorted(bad_slots):
            self._evict_slot(i)
        if bad_slots and not self.external_restore:
            self._restore_pending()
        return len(blocks)

    def _row_checksums(self, blocks) -> np.ndarray:
        """Checksums for ``blocks`` — index the one full-pool reduction,
        so every call shape hits the same compiled program."""
        sums = np.asarray(self._checksum(self.caches))
        return sums[np.asarray(blocks, np.int32)]

    def _seal_rows(self, blocks) -> None:
        """Digest freshly-finalized (full, no-longer-written) blocks."""
        if not self.harden:
            return
        bl = [int(b) for b in blocks if int(b) >= 0]
        if not bl:
            return
        for b, s in zip(bl, self._row_checksums(bl)):
            self.digests.seal(b, int(s))

    def _apply_armed_flips(self) -> None:
        """Land armed SEUs on sealed live blocks (deterministic per
        seed).  Flips that cannot land yet (no sealed KV live) stay
        armed — an upset in empty memory is harmless by definition."""
        held = {int(b) for row in self.table for b in row if b >= 0}
        targets = sorted(b for b in held if b in self.digests)
        still_armed: List[int] = []
        for seed in self._armed_flips:
            if not targets:
                still_armed.append(seed)
                continue
            rng = np.random.default_rng(seed)
            b = int(targets[int(rng.integers(len(targets)))])
            key = sorted(self.caches)[int(rng.integers(len(self.caches)))]
            st = self.caches[key]
            which = int(rng.integers(2))
            pool = st.k_pool if which == 0 else st.v_pool
            sh = pool.shape                  # [S, NB+1, P, KVp, hd]
            coord = (int(rng.integers(sh[0])), b,
                     int(rng.integers(sh[2])), int(rng.integers(sh[3])),
                     int(rng.integers(sh[4])))
            nbits = jnp.dtype(pool.dtype).itemsize * 8
            bit = int(rng.integers(nbits))
            u = jnp.uint16 if nbits == 16 else jnp.uint32
            el = jax.lax.bitcast_convert_type(pool[coord], u)
            el = jax.lax.bitcast_convert_type(el ^ u(1 << bit), pool.dtype)
            pool = pool.at[coord].set(el)
            self.caches[key] = (st._replace(k_pool=pool) if which == 0
                                else st._replace(v_pool=pool))
            t = time.perf_counter()
            self._stage("seu_bitflip", t, t, [], block=b, bit=bit,
                        seed=seed)
        self._armed_flips = still_armed

    def _watchdog(self) -> None:
        """Age occupied stalled slots; past the threshold, evict the
        request for replay and quarantine the slot from admission."""
        for i in sorted(self.stalled):
            s = self.slots[i]
            if s is None or i in self._tripped:
                continue
            self._stall_age[i] += 1
            if self._stall_age[i] < self.watchdog_steps:
                continue
            self.watchdog_trips += 1
            self._tripped.add(i)
            self._stall_age[i] = 0
            t = time.perf_counter()
            self._stage("watchdog_trip", t, t, [s.req.rid], slot=i,
                        tokens=len(s.gen))
            self._evict_slot(i)

    def _evict_slot(self, i: int) -> None:
        """Tear a slot down for replay: free its row exactly (shared
        refcounts honored, quarantined blocks skipped by the allocator)
        and queue (request, tokens-so-far) for restoration."""
        s = self.slots[i]
        self.alloc.release(
            self.shared.release(self.table[i][self.table[i] >= 0]))
        self.table[i] = -1
        self.lengths[i] = 0
        self._gen_counts[i] = 0
        self.slots[i] = None
        self._dirty = True
        self._restore_queue.append((s.req, list(s.gen)))

    def _on_corrupt_block(self, b: int) -> set:
        """Account one detected upset: quarantine the block, purge it
        from the shared index (sharers re-prefill fresh copies), drop
        its seal; returns the occupied slots whose rows hold it."""
        self.bitflips_detected += 1
        if self.alloc.quarantine(b):
            self.blocks_quarantined += 1
        self.shared.purge(b)
        self.digests.forget(b)
        t = time.perf_counter()
        self._stage("bitflip_detected", t, t, [], block=b)
        return {i for i in range(self.max_slots)
                if self.slots[i] is not None and b in self.table[i]}

    def _restore_pending(self) -> None:
        """Replay evicted requests into free, healthy slots (recovery
        runs before new admissions; deferred under block pressure)."""
        while self._restore_queue:
            req, gen = self._restore_queue[0]
            # only watchdog-proven slots are avoided — a latent stall the
            # system has not detected yet can catch a replay too (it will
            # trip and move on, same as fresh work)
            free = [i for i in range(self.max_slots)
                    if self.slots[i] is None and i not in self._tripped]
            if not free:
                break
            try:
                self._restore_slot(free[0], req, gen)
            except OutOfBlocksError:
                self.deferrals += 1
                break
            self._restore_queue.pop(0)
            self.replays += 1

    def _restore_slot(self, i: int, req: Request, gen: List[int]) -> None:
        """Rebuild an evicted in-flight request bit-exactly in slot
        ``i``: re-prefill its prompt (sharing prefix blocks via the
        content-hash index when still live — else replaying from the
        prompt), then replay the recorded generated tokens through the
        decode program.  The same programs that produced the original
        KV produce identical bits, and nothing is re-emitted, so the
        stream continues exactly-once from where it stopped."""
        s = int(req.prompt.shape[0])
        sp = req.sampling or GREEDY
        bs = self.block_size
        if s <= self.prompt_len:
            padded_len = self.prompt_len
            need = self._held_blocks()
            need[i] = -(-(self.prompt_len + req.max_new) // bs)
            self.table = paging.plan_blocks(self.table, self.alloc, need)
            self._push_tables()
            self._dirty = False
            toks = np.zeros((self.max_slots, self.prompt_len), np.int32)
            toks[i, -s:] = req.prompt
            admit = np.zeros(self.max_slots, bool)
            admit[i] = True
            self._temps[i], self._topks[i] = sp.temperature, sp.top_k
            self._seeds[i] = sp.seed
            self._knobs_dev = (jnp.asarray(self._temps),
                               jnp.asarray(self._topks),
                               jnp.asarray(self._seeds))
            t0 = time.perf_counter()
            _, self.caches = self._admit_step(
                self.params, jnp.asarray(toks), self._prefill_cache,
                self.caches, jnp.asarray(admit), *self._knobs_dev,
                not sp.greedy)
            self.admit_s += time.perf_counter() - t0
            self.prefill_tokens += self.prompt_len
            self.lengths[i] = self.prompt_len
        else:
            c = self.prefill_chunk
            length = -(-s // c) * c
            padded = np.zeros(length, np.int32)
            padded[length - s:] = req.prompt
            n_prompt_blocks = length // bs
            per_chunk = c // bs
            digests = []
            d = paging.SharedBlockIndex.ROOT
            for b in range(n_prompt_blocks):
                d = self.shared.chain(d, padded[b * bs:(b + 1) * bs])
                digests.append(d)
            hit = 0
            for b in range(n_prompt_blocks - per_chunk):
                if self.shared.lookup(digests[b]) is None:
                    break
                hit = b + 1
            shared_blocks = (hit // per_chunk) * per_chunk
            acquired = [self.shared.acquire(digests[b])
                        for b in range(shared_blocks)]
            self.table[i, :shared_blocks] = acquired
            need = self._held_blocks()
            need[i] = -(-(length + req.max_new) // bs)
            try:
                self.table = paging.plan_blocks(self.table, self.alloc,
                                                need)
            except OutOfBlocksError:
                self.shared.release(acquired)
                self.shared.hits -= len(acquired)
                self.table[i, :shared_blocks] = -1
                raise
            self._push_tables()
            self._dirty = False
            self._temps[i], self._topks[i] = sp.temperature, sp.top_k
            self._seeds[i] = sp.seed
            self._knobs_dev = (jnp.asarray(self._temps),
                               jnp.asarray(self._topks),
                               jnp.asarray(self._seeds))
            self._run_chunks(i, padded, shared_blocks * bs // c, sp,
                             rid=req.rid)
            for b in range(shared_blocks, n_prompt_blocks):
                self.shared.register(digests[b], int(self.table[i, b]))
            padded_len = length
            self.lengths[i] = length
        self._replay_generation(i, req, gen, padded_len, sp)

    def _replay_generation(self, i: int, req: Request, gen: List[int],
                           padded_len: int, sp: SamplingParams) -> None:
        """Replay recorded tokens ``gen[:-1]`` as decode inputs so the
        KV the lost steps had written is regrown bit-identically; every
        other row is masked off the device tables for the duration.
        Outputs are recomputed and discarded — nothing re-emits."""
        g = len(gen)
        t0 = time.perf_counter()
        if g > 1:
            mask_tbl = -np.ones_like(self.table)
            mask_tbl[i] = self.table[i]
            mask_len = np.zeros_like(self.lengths)
            mask_len[i] = padded_len
            self._push_tables(mask_tbl, mask_len)
            last = np.zeros((self.max_slots, 1), np.int32)
            for j in range(g - 1):
                last[i, 0] = gen[j]
                if self.harden:      # reuse the compiled hardened program
                    _, self.caches, _ = self._decode_h(
                        self.params, jnp.asarray(last), self.caches)
                else:
                    _, self.caches = self._decode(
                        self.params, jnp.asarray(last), self.caches)
        self.admit_s += time.perf_counter() - t0
        self.lengths[i] = padded_len + g - 1
        self._gen_counts[i] = g
        self.slots[i] = _Slot(req, list(gen), req.max_new - g,
                              sampled=not sp.greedy)
        self.last[i, 0] = gen[-1]
        self._dirty = True
        self._seal_rows(self.table[i][:self.lengths[i] // self.block_size])
        t1 = time.perf_counter()
        self._stage("replay", t0, t1, [req.rid], tokens=g,
                    slot=i)

    def restore_import(self, req: Request, gen: List[int],
                       handoff: "PrefillHandoff") -> None:
        """Disaggregated recovery: rebuild an evicted decode slot from a
        *fresh handoff* (the imported KV must reproduce the prefill
        engine's bits — the decode plan's own prefill might differ),
        then replay the recorded tokens.  Raises ``OutOfBlocksError``
        to defer under pressure; the caller parks the handoff payload so
        prefill compute is never repeated."""
        free = [j for j in range(self.max_slots)
                if self.slots[j] is None and j not in self._tripped]
        if not free:
            raise OutOfBlocksError("decode engine has no healthy free slot")
        i = free[0]
        bs, length = self.block_size, handoff.length
        need = self._held_blocks()
        need[i] = -(-(length + req.max_new) // bs)
        self.table = paging.plan_blocks(self.table, self.alloc, need)
        rows = self.table[i][:length // bs]
        self.caches = _paste_block_rows(self.caches, handoff.kv,
                                        jnp.asarray(rows))
        self._verify_import(i, req, handoff, rows)
        self.lengths[i] = length
        sp = req.sampling or GREEDY
        self._temps[i], self._topks[i] = sp.temperature, sp.top_k
        self._seeds[i] = sp.seed
        self._knobs_dev = (jnp.asarray(self._temps),
                           jnp.asarray(self._topks),
                           jnp.asarray(self._seeds))
        self._replay_generation(i, req, gen, length, sp)

    def _verify_import(self, i: int, req: Request,
                       handoff: "PrefillHandoff", rows) -> None:
        """Always-verify at handoff import: recompute the pasted rows'
        checksums against the digests stamped at gather time.  A
        mismatch (payload upset in transit) frees the planned row and
        raises — the seam re-requests the handoff.  Clean imports seal
        the rows with the already-computed sums."""
        if not self.harden or handoff.digests is None:
            return
        sums = self._row_checksums([int(b) for b in rows])
        if any(int(a) != int(e) for a, e in zip(sums, handoff.digests)):
            self.alloc.release(self.table[i][self.table[i] >= 0])
            self.table[i] = -1
            self._dirty = True
            raise HandoffCorruptError(
                f"handoff for request {req.rid} failed integrity verify")
        for b, s in zip(rows, sums):
            self.digests.seal(int(b), int(s))

    def _admit(self) -> List[Request]:
        admits: List[tuple] = []
        completed: List[Request] = []
        for i in range(self.max_slots):
            if not self.queue:
                break
            if self.slots[i] is not None or i in self._tripped:
                continue               # occupied, or watchdog-proven bad
            req = self.queue[0]
            if req.prompt.shape[0] > self.prompt_len:
                # over-bucket prompt: chunked paged prefill, one fused
                # chunk call at a time (shares prefix blocks when the
                # content-hash index has them live)
                res = self._admit_chunked(i, req)
                if res is _DEFER:
                    self.deferrals += 1
                    break
                self.queue.pop(0)
                if res is not None:
                    completed.append(res)
                continue
            need = self._held_blocks()
            need[i] = -(-(self.prompt_len + req.max_new) // self.block_size)
            try:
                self.table = paging.plan_blocks(self.table, self.alloc, need)
            except OutOfBlocksError:
                self.deferrals += 1    # defer admission; blocks will free
                break
            admits.append((i, self.queue.pop(0)))
        if not admits:
            return completed
        self._push_tables()                # freed + freshly-planned rows
        self._dirty = False
        # every admission this round rides one fused prefill+paste call;
        # each admitted request occupies its slot's batch row, dead rows
        # keep the compiled shape fixed
        toks = np.zeros((self.max_slots, self.prompt_len), np.int32)
        admit = np.zeros(self.max_slots, bool)
        any_sampled = False
        for i, req in admits:
            toks[i, -req.prompt.shape[0]:] = req.prompt      # left-pad
            admit[i] = True
            sp = req.sampling or GREEDY
            self._temps[i] = sp.temperature
            self._topks[i] = sp.top_k
            self._seeds[i] = sp.seed
            any_sampled |= not sp.greedy
        self._knobs_dev = (jnp.asarray(self._temps),
                           jnp.asarray(self._topks),
                           jnp.asarray(self._seeds))
        temps_d, topks_d, seeds_d = self._knobs_dev
        t0 = time.perf_counter()
        firsts, self.caches = self._admit_step(
            self.params, jnp.asarray(toks), self._prefill_cache,
            self.caches, jnp.asarray(admit), temps_d, topks_d, seeds_d,
            any_sampled)
        firsts = np.asarray(firsts)
        t1 = time.perf_counter()
        self.admit_s += t1 - t0
        self._stage("admit", t0, t1, [req.rid for _, req in admits],
                    tokens=self.prompt_len * len(admits))
        seal: List[int] = []
        for i, req in admits:
            self.lengths[i] = self.prompt_len
            self._gen_counts[i] = 1
            self.prefill_tokens += self.prompt_len
            if self.harden and req.max_new > 1:   # staying: seal the full
                seal.extend(                      # prompt blocks now
                    int(b) for b in
                    self.table[i][:self.prompt_len // self.block_size])
            tok = int(firsts[i])
            if req.max_new >= 1:
                # the admission token only counts when it is actually
                # emitted: a max_new=0 request produces no tokens, and
                # counting its prefill argmax inflated tokens/s
                self.total_tokens += 1
                self._emit(req.rid, tok)
            if req.max_new <= 1:       # done at admission (0 => empty,
                completed.append(       # matching the windowed baseline)
                    self._finalize(i, req, [tok][:req.max_new]))
            else:
                sp = req.sampling or GREEDY
                self.slots[i] = _Slot(req, [tok], req.max_new - 1,
                                      sampled=not sp.greedy)
                self.last[i, 0] = tok
        self._seal_rows(seal)
        return completed

    def _run_chunks(self, i: int, padded: np.ndarray, first_chunk: int,
                    sp: SamplingParams, rid: Optional[int] = None) -> int:
        """Drive the jitted chunk program over ``padded``'s chunks from
        ``first_chunk`` on; returns the final chunk's sampled token."""
        c = self.prefill_chunk
        temps1 = jnp.asarray([sp.temperature], jnp.float32)
        topks1 = jnp.asarray([sp.top_k], jnp.int32)
        seeds1 = jnp.asarray([sp.seed], jnp.int32)
        t0 = time.perf_counter()
        firsts = None
        ct0 = t0
        for ci in range(first_chunk, padded.shape[0] // c):
            firsts, self.caches = self._chunk_step(
                self.params, jnp.asarray(padded[ci * c:(ci + 1) * c][None]),
                self.caches, np.int32(i), np.int32(ci * c),
                temps1, topks1, seeds1, not sp.greedy)
            if self.on_stage is not None:
                ct1 = time.perf_counter()
                self._stage("prefill_chunk", ct0, ct1, [rid],
                            chunk=ci, tokens=c)
                ct0 = ct1
        self.admit_s += time.perf_counter() - t0
        self.prefill_tokens += padded.shape[0] - first_chunk * c
        return int(np.asarray(firsts)[0])

    def _admit_chunked(self, i: int, req: Request):
        """Admit one over-bucket prompt into slot ``i`` via chunked
        paged prefill.

        The prompt is left-padded to a whole number of prefill chunks
        (generalizing the bucket's left-pad), its full block budget
        (padded prompt + max_new) reserved atomically, and each chunk
        runs one fused prefill+paste call — the final chunk's is also
        the admit+sample step, exactly like the bucket path.  Before
        allocating, the content-hashed :class:`~repro.runtime.paging.
        SharedBlockIndex` is consulted: a live identical prompt prefix
        (whole chunks only — the final chunk always recomputes, its
        last-token logits seed sampling) is reference-shared instead of
        re-prefilled.  Returns the completed Request for ``max_new<=1``,
        None when the request now occupies the slot, or ``_DEFER`` when
        the pool cannot cover it yet (nothing leaks; retried later)."""
        bs, c = self.block_size, self.prefill_chunk
        s = int(req.prompt.shape[0])
        length = -(-s // c) * c
        padded = np.zeros(length, np.int32)
        padded[length - s:] = req.prompt
        n_prompt_blocks = length // bs
        per_chunk = c // bs
        digests = []
        d = paging.SharedBlockIndex.ROOT
        for b in range(n_prompt_blocks):
            d = self.shared.chain(d, padded[b * bs:(b + 1) * bs])
            digests.append(d)
        hit = 0
        for b in range(n_prompt_blocks - per_chunk):
            if self.shared.lookup(digests[b]) is None:
                break
            hit = b + 1
        shared_blocks = (hit // per_chunk) * per_chunk
        acquired = [self.shared.acquire(digests[b])
                    for b in range(shared_blocks)]
        self.table[i, :shared_blocks] = acquired
        need = self._held_blocks()
        need[i] = -(-(length + req.max_new) // bs)
        try:
            self.table = paging.plan_blocks(self.table, self.alloc, need)
        except OutOfBlocksError:
            self.shared.release(acquired)   # refs only; owners keep blocks
            self.shared.hits -= len(acquired)   # retry will re-count them
            self.table[i, :shared_blocks] = -1
            return _DEFER
        self._push_tables()
        self._dirty = False
        sp = req.sampling or GREEDY
        self._temps[i], self._topks[i] = sp.temperature, sp.top_k
        self._seeds[i] = sp.seed
        self._knobs_dev = (jnp.asarray(self._temps),
                           jnp.asarray(self._topks),
                           jnp.asarray(self._seeds))
        tok = self._run_chunks(i, padded, shared_blocks * bs // c, sp,
                               rid=req.rid)
        # publish the freshly prefilled prompt blocks for future sharers
        # (all are full, read-only blocks: decode appends start a new
        # block because the padded length is block-aligned)
        for b in range(shared_blocks, n_prompt_blocks):
            self.shared.register(digests[b], int(self.table[i, b]))
        self.lengths[i] = length
        self._gen_counts[i] = 1
        if self.harden and req.max_new > 1:
            # freshly prefilled prompt blocks are full + read-only from
            # here on (shared-index hits were sealed by their writer)
            self._seal_rows(self.table[i][shared_blocks:n_prompt_blocks])
        if req.max_new >= 1:
            self.total_tokens += 1
            self._emit(req.rid, tok)
        if req.max_new <= 1:
            return self._finalize(i, req, [tok][:req.max_new])
        self.slots[i] = _Slot(req, [tok], req.max_new - 1,
                              sampled=not sp.greedy)
        self.last[i, 0] = tok
        return None

    def _decode_once(self) -> List[Request]:
        # stalled slots occupy their row but make no progress — the
        # latched fault is latent until the watchdog trips it
        active = [i for i, s in enumerate(self.slots)
                  if s is not None and i not in self.stalled]
        if not active:
            return []
        if self._dirty:
            self._push_tables()
            self._dirty = False
        any_sampled = any(s is not None and s.sampled for s in self.slots)
        t0 = time.perf_counter()
        sums_np: Optional[np.ndarray] = None
        if self.harden:
            # hardened dispatch: the same decode plus a per-block
            # checksum of the whole pool, fused — detection lands the
            # same step a corrupted block is read, before any token of
            # this step escapes to a stream
            if any_sampled:
                temps_d, topks_d, seeds_d = self._knobs_dev
                nxt, self.caches, sums = self._decode_with_h(
                    self.params, jnp.asarray(self.last), self.caches,
                    temps_d, topks_d, seeds_d,
                    jnp.asarray(self._gen_counts))
            else:
                nxt, self.caches, sums = self._decode_h(
                    self.params, jnp.asarray(self.last), self.caches)
            sums_np = np.asarray(sums)
        elif any_sampled:
            temps_d, topks_d, seeds_d = self._knobs_dev
            nxt, self.caches = self._decode_with(
                self.params, jnp.asarray(self.last), self.caches,
                temps_d, topks_d, seeds_d, jnp.asarray(self._gen_counts))
        else:
            nxt, self.caches = self._decode(
                self.params, jnp.asarray(self.last), self.caches)
        nxt = np.asarray(nxt)
        t1 = time.perf_counter()
        self.decode_s += t1 - t0
        self._stage("decode_step", t0, t1,
                    [self.slots[i].req.rid for i in active],
                    step=self.decode_steps, tokens=len(active))
        bad_slots: set = set()
        if sums_np is not None and len(self.digests):
            # every sealed block is verified every step — the full-pool
            # reduction makes idle sealed blocks free to check too
            items = self.digests.items()
            blks = np.fromiter((b for b, _ in items), np.int64, len(items))
            seals = np.fromiter((d for _, d in items), np.int64, len(items))
            for b in blks[sums_np[blks] != seals]:
                bad_slots |= self._on_corrupt_block(int(b))
        completed: List[Request] = []
        emitted = 0
        for i in active:
            if i in bad_slots:
                continue       # computed from corrupted KV: never emits
            self.lengths[i] += 1           # mirror device append_tokens
            self._gen_counts[i] += 1
            s = self.slots[i]
            tok = int(nxt[i])
            s.gen.append(tok)
            s.remaining -= 1
            self.last[i, 0] = nxt[i]
            self._emit(s.req.rid, tok)
            emitted += 1
            if self.harden and self.lengths[i] % self.block_size == 0:
                # this step's append just filled a block: its fused sum
                # is the seal (no extra device call on the hot path)
                b = int(self.table[i,
                                   self.lengths[i] // self.block_size - 1])
                if 0 <= b < self.alloc.num_blocks and sums_np is not None:
                    self.digests.seal(b, int(sums_np[b]))
            if s.remaining <= 0:
                completed.append(self._finalize(i, s.req, s.gen))
                self.slots[i] = None
        for i in sorted(bad_slots):
            self._evict_slot(i)
        self.decode_steps += 1
        self.total_tokens += emitted
        self.decode_tokens += emitted
        self.occupancy_sum += len(active) / self.max_slots
        return completed

    def _finalize(self, i: int, req: Request, gen: List[int]) -> Request:
        req.output = np.asarray(gen, np.int32)
        self.done[req.rid] = req
        # shared prompt blocks are refcounted by the content-hash index
        # (freed with their last referencing sequence); the rest of the
        # row goes straight back to the allocator
        self.alloc.release(
            self.shared.release(self.table[i][self.table[i] >= 0]))
        self.table[i] = -1
        self.lengths[i] = 0
        self._dirty = True        # device sees the freed row at next push
        return req

    # ------------------------------------------------------------------
    # co-processing handoff (prefill-class <-> decode-class engines)
    # ------------------------------------------------------------------
    def prefill_handoff(self, req: Request) -> "PrefillHandoff":
        """Run ``req``'s prompt through chunked paged prefill on THIS
        engine and export the block-level KV for a peer decode engine —
        the MPAI DPU->VPU handoff.  The prompt is left-padded to the
        engine's bucket/chunk grid exactly like a unified admission, the
        final chunk samples the first output token (under this engine's
        precision plan — the prefill stage owns it), and the filled
        blocks are gathered out and freed before returning: the handoff
        carries KV *content*; the importer re-blocks it into its own
        mirrored pool.  Raises :class:`OutOfBlocksError` when the prompt
        cannot be covered right now (atomic — callers defer and retry)."""
        _require_prompt(req, "engine")
        bs, c = self.block_size, self.prefill_chunk
        length = -(-max(int(req.prompt.shape[0]), self.prompt_len) // c) * c
        free = [j for j, sl in enumerate(self.slots)
                if sl is None and j not in self._tripped]
        if not free:
            raise OutOfBlocksError("prefill engine has no free slot")
        i = free[0]
        padded = np.zeros(length, np.int32)
        padded[length - req.prompt.shape[0]:] = req.prompt
        need = self._held_blocks()
        need[i] = length // bs            # prompt only: no decode budget
        self.table = paging.plan_blocks(self.table, self.alloc, need)
        self._push_tables()
        self._dirty = False
        sp = req.sampling or GREEDY
        tok = self._run_chunks(i, padded, 0, sp, rid=req.rid)
        if req.rid in self.mute_rids:
            # replayed handoff (the original was lost/corrupted after its
            # first token already streamed): recompute deterministically,
            # emit nothing — exactly-once delivery across the seam
            self.mute_rids.discard(req.rid)
        elif req.max_new >= 1:
            self.total_tokens += 1
            self._emit(req.rid, tok)
        rows = self.table[i][:length // bs].copy()
        g0 = time.perf_counter()
        kv = _gather_block_rows(self.caches, jnp.asarray(rows))
        digests = None
        if self.harden:
            # stamp the payload's per-block checksums before the blocks
            # free — the importer verifies the paste against them
            digests = tuple(int(s) for s in
                            self._row_checksums([int(b) for b in rows]))
        self._stage("handoff", g0, time.perf_counter(), [req.rid],
                    blocks=len(rows), tokens=length)
        self.alloc.release(self.shared.release(rows))
        self.table[i] = -1
        self.lengths[i] = 0
        self._dirty = True
        return PrefillHandoff(req.rid, tok, length, self.block_size, kv,
                              digests)

    def import_prefill(self, req: Request,
                       handoff: "PrefillHandoff") -> Optional[Request]:
        """Admit a request whose prompt KV a co-processing peer already
        prefilled: reserve the full block budget, paste the handed-off
        blocks into this engine's mirrored pool, and resume at decode
        with the peer's first sampled token (emitted there — importing
        never double-counts or double-streams it).  Returns the
        completed Request for ``max_new<=1``, else None; raises
        :class:`OutOfBlocksError` when blocks are short (callers defer)."""
        assert handoff.block_size == self.block_size, \
            (f"mirrored pools must share block geometry: handoff wrote "
             f"{handoff.block_size}-token blocks, this pool holds "
             f"{self.block_size}-token blocks")
        free = [j for j, sl in enumerate(self.slots)
                if sl is None and j not in self._tripped]
        if not free:
            raise OutOfBlocksError("decode engine has no free slot")
        i = free[0]
        bs, length = self.block_size, handoff.length
        assert length + req.max_new <= self.table_width * bs, \
            (req.rid, length, req.max_new, self.max_len)
        need = self._held_blocks()
        need[i] = -(-(length + req.max_new) // bs)
        self.table = paging.plan_blocks(self.table, self.alloc, need)
        rows = self.table[i][:length // bs]
        p0 = time.perf_counter()
        self.caches = _paste_block_rows(self.caches, handoff.kv,
                                        jnp.asarray(rows))
        self._stage("import", p0, time.perf_counter(), [req.rid],
                    blocks=len(rows), tokens=length)
        self._verify_import(i, req, handoff, rows)
        self.lengths[i] = length
        self._gen_counts[i] = 1
        self._dirty = True                # table + lengths push next step
        sp = req.sampling or GREEDY
        self._temps[i], self._topks[i] = sp.temperature, sp.top_k
        self._seeds[i] = sp.seed
        self._knobs_dev = (jnp.asarray(self._temps),
                           jnp.asarray(self._topks),
                           jnp.asarray(self._seeds))
        tok = handoff.first_token
        if req.max_new <= 1:
            return self._finalize(i, req, [tok][:req.max_new])
        self.slots[i] = _Slot(req, [tok], req.max_new - 1,
                              sampled=not sp.greedy)
        self.last[i, 0] = tok
        return None


# ---------------------------------------------------------------------------
# Prefill/decode disaggregation (MPAI co-processing)
# ---------------------------------------------------------------------------
class HandoffCorruptError(RuntimeError):
    """A PrefillHandoff payload failed its integrity verify at import —
    the seam re-requests the handoff (prefill is deterministic, so the
    replacement carries identical bits and the already-streamed first
    token stays valid)."""


class HandoffWireError(ValueError):
    """A PrefillHandoff wire buffer is structurally unusable — wrong
    magic, unknown schema version, or a truncated/overlong frame.
    Distinct from :class:`HandoffCorruptError` (checksum mismatch on an
    intact frame): corruption is re-requested by the seam; a wire error
    means the two ends do not speak the same format and retrying the
    same bytes cannot help."""


#: PrefillHandoff wire schema version.  Bump on ANY layout change to
#: ``to_bytes`` — field order, widths, the array encoding, or the
#: checksum construction — and keep ``from_bytes`` rejecting everything
#: it does not speak: a decode pod on an older image must fail loudly,
#: never misparse.  Versioning rules: ``repro/serving/WIRE_FORMAT.md``.
WIRE_VERSION = 1
_WIRE_MAGIC = b"MPAI"
_WIRE_HEADER = "<HQI"                  # version, payload length, checksum


def _wire_dtype(name: str) -> np.dtype:
    """Resolve a serialized dtype name, including the ml_dtypes extras
    (bfloat16, float8_*) that numpy cannot name on its own."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        try:
            return np.dtype(getattr(ml_dtypes, name))
        except AttributeError:
            raise HandoffWireError(
                f"handoff wire carries unknown dtype {name!r}") from None


def _pack_array(a: np.ndarray) -> bytes:
    name = a.dtype.name.encode()
    raw = np.ascontiguousarray(a).tobytes()
    return b"".join([
        struct.pack("<i", len(name)), name,
        struct.pack("<i", a.ndim),
        struct.pack(f"<{a.ndim}q", *a.shape),
        struct.pack("<q", len(raw)), raw])


@dataclass
class PrefillHandoff:
    """One prefilled prompt crossing the co-processing seam.

    Produced by :meth:`ContinuousBatchingEngine.prefill_handoff` on the
    prefill-class engine, consumed by
    :meth:`ContinuousBatchingEngine.import_prefill` on the decode-class
    engine.  Carries the first sampled token (the prefill stage owns
    admission sampling), the padded prompt length, and the block-level
    KV per sublayer — ``kv[key] = (k, v)`` with shape
    ``[n_super, n_blocks, P, KVp, hd]`` — in the shared block geometry
    both mirrored pools were built with.

    The handoff is also a *wire format*: ``to_bytes``/``from_bytes``
    serialize it losslessly (bit-exact KV round-trip) under a schema
    version and a whole-frame integrity checksum, so the seam behaves
    identically whether the importer shares the exporter's address
    space or sits across a process/host boundary.  Every
    :class:`CoProcServer` handoff crosses the seam in wire form.
    """
    rid: int
    first_token: int
    length: int                        # padded prompt length (tokens)
    block_size: int
    kv: Dict[str, tuple]
    # per-block integrity checksums stamped at gather time (hardened
    # prefill engines only): the importer recomputes them after pasting
    # and rejects the handoff on mismatch — an upset on the interconnect
    # never becomes served tokens
    digests: Optional[tuple] = None

    # ------------------------------------------------------------------
    # wire format (versioned, integrity-checked)
    # ------------------------------------------------------------------
    def to_bytes(self) -> bytes:
        """Serialize to the versioned wire frame::

            MAGIC(4) | version(u16) | payload_len(u64) | checksum(u32)
            payload: rid(i64) first_token(i32) length(i32)
                     block_size(i32) digests(i32 count, -1=None, u32*)
                     kv(i32 count; per sorted key: name, K arr, V arr)

        Arrays carry dtype name + shape + raw bytes, so the round-trip
        is bit-exact for every pool dtype (bf16/fp16/fp32 included).
        The checksum is :func:`repro.runtime.paging.wire_checksum` over
        the payload — the PR-7 block-checksum construction applied to
        the frame — so an interconnect upset is caught before the
        importer pastes a single block.
        """
        parts = [struct.pack("<qiii", self.rid, self.first_token,
                             self.length, self.block_size)]
        if self.digests is None:
            parts.append(struct.pack("<i", -1))
        else:
            digs = [int(d) & 0xFFFFFFFF for d in self.digests]
            parts.append(struct.pack(f"<i{len(digs)}I", len(digs), *digs))
        parts.append(struct.pack("<i", len(self.kv)))
        for key in sorted(self.kv):
            k, v = self.kv[key]
            kb = key.encode()
            parts.append(struct.pack("<i", len(kb)) + kb)
            parts.append(_pack_array(np.asarray(k)))
            parts.append(_pack_array(np.asarray(v)))
        payload = b"".join(parts)
        head = _WIRE_MAGIC + struct.pack(
            _WIRE_HEADER, WIRE_VERSION, len(payload),
            paging.wire_checksum(payload))
        return head + payload

    @classmethod
    def from_bytes(cls, buf: bytes) -> "PrefillHandoff":
        """Parse a wire frame back into a handoff.

        Raises :class:`HandoffWireError` on structural problems (bad
        magic, version mismatch, truncation — retrying the same bytes
        cannot help) and :class:`HandoffCorruptError` on a checksum
        mismatch over an intact frame (an in-transit upset — the seam
        re-requests the handoff exactly-once).
        """
        buf = bytes(buf)
        hdr = 4 + struct.calcsize(_WIRE_HEADER)
        if len(buf) < hdr:
            raise HandoffWireError(
                f"handoff frame truncated: {len(buf)} bytes < {hdr}-byte "
                f"header")
        if buf[:4] != _WIRE_MAGIC:
            raise HandoffWireError(
                f"not a PrefillHandoff frame (magic {buf[:4]!r})")
        version, plen, want = struct.unpack_from(_WIRE_HEADER, buf, 4)
        if version != WIRE_VERSION:
            raise HandoffWireError(
                f"handoff wire version {version} != {WIRE_VERSION}; both "
                f"seam ends must run the same schema")
        payload = buf[hdr:]
        if len(payload) != plen:
            raise HandoffWireError(
                f"handoff frame truncated: payload {len(payload)} bytes, "
                f"header declares {plen}")
        if paging.wire_checksum(payload) != want:
            raise HandoffCorruptError(
                "handoff frame failed its wire checksum (payload upset "
                "in transit)")
        off = 0

        def take(fmt):
            nonlocal off
            size = struct.calcsize(fmt)
            if off + size > len(payload):
                raise HandoffWireError(
                    "handoff payload underruns its declared structure")
            vals = struct.unpack_from(fmt, payload, off)
            off += size
            return vals

        def take_array():
            nonlocal off
            (nlen,) = take("<i")
            dtype = _wire_dtype(payload[off:off + nlen].decode())
            off += nlen
            (ndim,) = take("<i")
            shape = take(f"<{ndim}q")
            (rlen,) = take("<q")
            if off + rlen > len(payload):
                raise HandoffWireError(
                    "handoff payload underruns its declared structure")
            a = np.frombuffer(payload, dtype, offset=off,
                              count=int(np.prod(shape, dtype=np.int64))
                              ).reshape(shape)
            off += rlen
            return jnp.asarray(a)

        rid, first_token, length, block_size = take("<qiii")
        (ndig,) = take("<i")
        digests = None if ndig < 0 else tuple(take(f"<{ndig}I"))
        (nkv,) = take("<i")
        kv = {}
        for _ in range(nkv):
            (klen,) = take("<i")
            key = payload[off:off + klen].decode()
            off += klen
            kv[key] = (take_array(), take_array())
        return cls(rid, first_token, length, block_size, kv, digests)


class CoProcServer:
    """Disaggregated serving: a prefill-class engine fanning out to N
    decode-shard engines over mirrored paged pools.

    The MPAI co-processing split as a server: stage 1 (the DPU
    analogue — typically a cheap/int8 precision plan) runs chunked
    paged prefill and samples the first token; stage 2 (the VPU
    analogue) imports the filled blocks into its own pool and decodes.
    Stage 2 may be *sharded*: N identical decode engines, each with its
    own allocator and slots, fed from the single prefill stage.  Every
    handoff crosses the seam in :meth:`PrefillHandoff.to_bytes` wire
    form (versioned + checksummed), so the fan-out behaves identically
    whether the shards share the exporter's process or not.  Importer
    selection is least-loaded per request; seam backpressure is tracked
    per shard (a full shard defers to the next, and only when *every*
    live shard defers does the request park at the seam).  A
    lost/corrupt frame is re-requested exactly-once: prefill replays
    muted (deterministic, bit-identical), so delivered tokens stay
    delivered once.

    Exposes the same ``submit`` / ``step`` / ``flush`` / ``done`` /
    ``stats`` API as the engines, so
    :class:`~repro.serving.executor.EngineExecutor` drives it
    unchanged; per-stage counters (``prefill_tokens`` / ``admit_s`` on
    the prefill engine, decode counters summed over shards) let the
    executor charge each stage to its own pool telemetry, with
    ``imports_by_shard`` splitting the seam traffic per consumer.
    """

    def __init__(self, prefill_engine: ContinuousBatchingEngine,
                 decode_engines: Union[ContinuousBatchingEngine,
                                       Sequence[ContinuousBatchingEngine]]):
        if isinstance(decode_engines, ContinuousBatchingEngine):
            decode_engines = [decode_engines]
        self.decodes: List[ContinuousBatchingEngine] = list(decode_engines)
        assert self.decodes, "need at least one decode shard"
        for eng in self.decodes:
            assert prefill_engine.block_size == eng.block_size, \
                "mirrored pools must share block geometry"
        self.prefill = prefill_engine
        self.max_len = min(e.max_len for e in self.decodes)
        self.prompt_len = self.decodes[0].prompt_len
        self.queue: List[Request] = []
        self._parked: Optional[tuple] = None   # (req, handoff) at the seam
        self.handoff_count = 0
        self._seam_deferrals = 0
        self._on_token: Optional[Callable[[int, int], None]] = None
        self._on_stage = None
        # radiation hardening at the seam: each shard's evictions come
        # back through a *fresh handoff* (its imported KV must carry the
        # prefill engine's bits — replaying prefill under the decode
        # plan would not), so the seam owns every shard's restore queue
        for eng in self.decodes:
            eng.external_restore = True
        self._restore_parked: Optional[tuple] = None  # (shard, req, gen, ho)
        self._lose_handoffs = 0        # armed handoff_loss faults
        self._corrupt_wire = 0         # armed in-transit frame upsets
        self.handoffs_lost = 0
        self.handoffs_replayed = 0
        self._draining: set = set()    # shard indices leaving the rotation
        self.imports_by_shard: Dict[str, int] = {
            f"shard{i}": 0 for i in range(len(self.decodes))}
        self.seam_deferrals_by_shard: Dict[str, int] = {
            f"shard{i}": 0 for i in range(len(self.decodes))}

    # --- back-compat single-shard view --------------------------------
    @property
    def decode(self) -> ContinuousBatchingEngine:
        """The first decode shard (the whole stage when unsharded)."""
        return self.decodes[0]

    @property
    def decode_shards(self) -> int:
        return len(self.decodes)

    # --- token relay: all stages emit through one hook ----------------
    @property
    def on_token(self):
        return self._on_token

    @on_token.setter
    def on_token(self, fn) -> None:
        self._on_token = fn
        self.prefill.on_token = fn         # first token, at the handoff
        for eng in self.decodes:
            eng.on_token = fn              # everything after

    # --- stage relay: engine stage names are disjoint (prefill:
    # admit/prefill_chunk/handoff; decode: import/decode_step), and each
    # decode shard tags its spans with its index so the fan-out is
    # visible per consumer -----------------------------------------------
    @property
    def on_stage(self):
        return self._on_stage

    @on_stage.setter
    def on_stage(self, fn) -> None:
        self._on_stage = fn
        self.prefill.on_stage = fn
        for i, eng in enumerate(self.decodes):
            if fn is None:
                eng.on_stage = None
            else:
                def relay(stage, t0, t1, rids, attrs, _i=i, _fn=fn):
                    _fn(stage, t0, t1, rids, {**attrs, "shard": _i})
                eng.on_stage = relay

    # --- mirrored engine API ------------------------------------------
    @property
    def done(self) -> Dict[int, Request]:
        if len(self.decodes) == 1:
            return self.decodes[0].done
        merged: Dict[int, Request] = {}
        for eng in self.decodes:
            merged.update(eng.done)
        return merged

    @property
    def pending(self) -> int:
        return (len(self.queue) + (self._parked is not None)
                + (self._restore_parked is not None)
                + sum(e.pending for e in self.decodes))

    # --- radiation hardening: fault API + counters --------------------
    @property
    def harden(self) -> bool:
        return all(e.harden for e in self.decodes)

    def inject_handoff_loss(self) -> None:
        """Arm one seam SEU: the next handoff payload vanishes between
        gather and import and must be re-requested."""
        self._lose_handoffs += 1

    def inject_handoff_corruption(self) -> None:
        """Arm one in-transit SEU: a byte of the next wire frame flips
        between export and import.  The frame checksum catches it and
        the seam re-requests the handoff exactly-once."""
        self._corrupt_wire += 1

    def arm_bitflip(self, seed: int = 0) -> None:
        # live KV lives in the decode pools (prefill rows free at gather)
        self.decodes[0].arm_bitflip(seed)

    def stall_slot(self, slot: int) -> None:
        self.decodes[0].stall_slot(slot)

    def unstall_slot(self, slot: int) -> None:
        self.decodes[0].unstall_slot(slot)

    def scrub(self, budget: Optional[int] = None) -> int:
        n = self.prefill.scrub(budget)
        for eng in self.decodes:
            n += eng.scrub(budget)
        return n

    @property
    def bitflips_detected(self) -> int:
        return (self.prefill.bitflips_detected
                + sum(e.bitflips_detected for e in self.decodes))

    @property
    def blocks_quarantined(self) -> int:
        return (self.prefill.blocks_quarantined
                + sum(e.blocks_quarantined for e in self.decodes))

    @property
    def watchdog_trips(self) -> int:
        return (self.prefill.watchdog_trips
                + sum(e.watchdog_trips for e in self.decodes))

    @property
    def replays(self) -> int:
        return self.prefill.replays + sum(e.replays for e in self.decodes)

    @property
    def scrubbed_blocks(self) -> int:
        return (self.prefill.scrubbed_blocks
                + sum(e.scrubbed_blocks for e in self.decodes))

    @property
    def occupancy(self) -> float:
        slots = sum(e.max_slots for e in self.decodes)
        busy = sum(e.occupancy * e.max_slots for e in self.decodes)
        return busy / slots

    @property
    def decode_steps(self) -> int:
        return sum(e.decode_steps for e in self.decodes)

    @property
    def decode_tokens(self) -> int:
        return sum(e.decode_tokens for e in self.decodes)

    @property
    def decode_s(self) -> float:
        return sum(e.decode_s for e in self.decodes)

    @property
    def total_tokens(self) -> int:
        return (self.prefill.total_tokens
                + sum(e.total_tokens for e in self.decodes))

    @property
    def prefill_tokens(self) -> int:
        return self.prefill.prefill_tokens

    @property
    def admit_s(self) -> float:
        return self.prefill.admit_s

    @property
    def deferrals(self) -> int:
        return (self.prefill.deferrals
                + sum(e.deferrals for e in self.decodes)
                + self._seam_deferrals)

    @property
    def prefix_hits(self) -> int:
        return (self.prefill.prefix_hits
                + sum(e.prefix_hits for e in self.decodes))

    @property
    def prefix_lookups(self) -> int:
        return (self.prefill.prefix_lookups
                + sum(e.prefix_lookups for e in self.decodes))

    def padded_prompt_len(self, s: int) -> int:
        # the prefill-class engine's chunk grid decides the padded
        # length crossing the seam (its chunks may be wider than the
        # decode engine's bucket — the DPU-analogue is a *wide* engine)
        c = self.prefill.prefill_chunk
        return -(-max(s, self.prefill.prompt_len) // c) * c

    def submit(self, req: Request) -> None:
        _require_prompt(req, "engine")
        padded = self.padded_prompt_len(int(req.prompt.shape[0]))
        budget = min(e.table_width * e.block_size for e in self.decodes)
        assert padded + req.max_new <= budget, \
            (req.rid, req.prompt.shape[0], req.max_new, self.max_len)
        self.queue.append(req)

    # --- shard lifecycle ----------------------------------------------
    def retire_shard(self, idx: int) -> None:
        """Drain decode shard ``idx``: it leaves the import rotation
        immediately but keeps stepping until its in-flight streams
        finish — zero dropped streams, matching pool retirement
        semantics one layer up."""
        if not 0 <= idx < len(self.decodes):
            raise IndexError(f"no decode shard {idx}")
        live = [i for i in range(len(self.decodes))
                if i not in self._draining]
        if live == [idx]:
            raise ValueError("cannot retire the last live decode shard")
        self._draining.add(idx)

    def _import_order(self) -> List[int]:
        """Live shards, least-loaded first (ties broken by index — the
        deterministic routing the bit-identity guarantee relies on)."""
        live = [i for i in range(len(self.decodes))
                if i not in self._draining]
        return sorted(live, key=lambda i: (self.decodes[i].pending, i))

    def _transport(self, handoff: PrefillHandoff) -> PrefillHandoff:
        """Cross the seam: serialize to the wire frame and parse it
        back, exactly what a process/host boundary would do.  An armed
        in-transit upset flips one payload byte; the frame checksum
        turns that into :class:`HandoffCorruptError` before any block
        is pasted."""
        wire = handoff.to_bytes()
        if self._corrupt_wire > 0:
            self._corrupt_wire -= 1
            wire = wire[:-1] + bytes([wire[-1] ^ 0x40])
        return PrefillHandoff.from_bytes(wire)

    def step(self) -> List[Request]:
        """Move work across the handoff seam, then run one decode step
        on every shard.

        Per step: prefill queued requests (stage 1) and import each
        into the least-loaded live decode shard (stage 2) while blocks
        and slots allow; a shard hitting backpressure defers to the
        next, a fully-backed-up stage parks the request without losing
        the other stage's progress, and exactly-once token delivery
        holds across the seam (the first token streams from the prefill
        stage, the importing shard resumes at token index 1)."""
        completed: List[Request] = []
        self._drain_restores()             # recovery before fresh work
        while True:
            if self._parked is None:
                if not self.queue:
                    break
                try:
                    ho = self.prefill.prefill_handoff(self.queue[0])
                except OutOfBlocksError:
                    self._seam_deferrals += 1
                    break
                req = self.queue.pop(0)
                if self._lose_handoffs > 0:
                    # armed seam SEU: the payload vanishes in transit.
                    # Its first token already streamed, so the re-request
                    # is muted — prefill determinism makes the replacement
                    # bit-identical and delivery stays exactly-once.
                    self._lose_handoffs -= 1
                    self.handoffs_lost += 1
                    self.handoffs_replayed += 1
                    self.prefill.mute_rids.add(req.rid)
                    self.queue.insert(0, req)
                    continue
                try:
                    ho = self._transport(ho)
                except HandoffCorruptError:
                    # frame upset caught by the wire checksum: same
                    # exactly-once re-request contract as a loss
                    self.handoffs_replayed += 1
                    self.prefill.mute_rids.add(req.rid)
                    self.queue.insert(0, req)
                    continue
                self._parked = (req, ho)
            req, ho = self._parked
            placed = corrupt = False
            done = None
            for si in self._import_order():
                try:
                    done = self.decodes[si].import_prefill(req, ho)
                except OutOfBlocksError:
                    self.seam_deferrals_by_shard[f"shard{si}"] += 1
                    continue               # next-least-loaded shard
                except HandoffCorruptError:
                    # the payload itself is bad — no other shard can
                    # import it; discard and re-request
                    corrupt = True
                    break
                placed = True
                break
            if corrupt:
                self._parked = None
                self.handoffs_replayed += 1
                self.prefill.mute_rids.add(req.rid)
                self.queue.insert(0, req)
                continue
            if not placed:
                self._seam_deferrals += 1  # every live shard deferred
                break
            self._parked = None
            self.handoff_count += 1
            self.imports_by_shard[f"shard{si}"] += 1
            if done is not None:
                completed.append(done)
        for eng in self.decodes:           # draining shards finish too
            completed += eng.step()
        return completed

    def _drain_restores(self) -> None:
        """Replay decode-side evictions (watchdog trips, quarantined
        blocks) across the seam: re-run the prefill handoff (muted — the
        delivered prefix stays delivered exactly once), import it back
        into a healthy slot *on the same shard*, and replay the recorded
        tokens.  Seam backpressure holds: a restore that cannot place
        yet parks with its handoff and retries next step without
        recomputing prefill."""
        while (self._restore_parked is not None
               or any(e._restore_queue for e in self.decodes)):
            if self._restore_parked is None:
                si = next(i for i, e in enumerate(self.decodes)
                          if e._restore_queue)
                eng = self.decodes[si]
                req, gen = eng._restore_queue[0]
                self.prefill.mute_rids.add(req.rid)
                try:
                    ho = self.prefill.prefill_handoff(req)
                except OutOfBlocksError:
                    self.prefill.mute_rids.discard(req.rid)
                    self._seam_deferrals += 1
                    return
                eng._restore_queue.pop(0)
                try:
                    ho = self._transport(ho)
                except HandoffCorruptError:
                    self.handoffs_replayed += 1
                    eng._restore_queue.insert(0, (req, gen))
                    continue
                self._restore_parked = (si, req, gen, ho)
            si, req, gen, ho = self._restore_parked
            eng = self.decodes[si]
            try:
                eng.restore_import(req, gen, ho)
            except HandoffCorruptError:
                self._restore_parked = None
                self.handoffs_replayed += 1
                eng._restore_queue.insert(0, (req, gen))
                continue
            except OutOfBlocksError:
                self._seam_deferrals += 1
                return
            self._restore_parked = None
            eng.replays += 1

    def flush(self) -> List[Request]:
        """Blocking form: run until at least one request completes."""
        if not self.pending:
            return []
        while True:
            done = self.step()
            if done:
                return done

    def stats(self) -> Dict[str, float]:
        shard_stats = [e.stats() for e in self.decodes]
        p = self.prefill.stats()
        d = dict(shard_stats[0])
        for s in shard_stats[1:]:
            for key, val in s.items():
                d[key] += val
        # mean occupancy does not sum — recompute weighted by steps
        steps = sum(s["decode_steps"] for s in shard_stats)
        d["mean_occupancy"] = (
            sum(s["mean_occupancy"] * s["decode_steps"]
                for s in shard_stats) / steps if steps
            else shard_stats[0]["mean_occupancy"])
        d["total_tokens"] = self.total_tokens
        d["prefill_tokens"] = p["prefill_tokens"]
        d["admit_s"] = p["admit_s"]            # prefill stage wall time
        d["shared_block_hits"] += p["shared_block_hits"]
        d["shared_block_lookups"] += p["shared_block_lookups"]
        d["deferrals"] = self.deferrals
        d["handoffs"] = self.handoff_count
        for key in ("bitflips_detected", "blocks_quarantined",
                    "watchdog_trips", "replays", "scrubbed_blocks"):
            d[key] = getattr(self, key)    # prefill + shard aggregate
        d["handoffs_lost"] = self.handoffs_lost
        d["handoffs_replayed"] = self.handoffs_replayed
        d["decode_shards"] = len(self.decodes)
        d["imports_by_shard"] = dict(self.imports_by_shard)
        d["seam_deferrals_by_shard"] = dict(self.seam_deferrals_by_shard)
        return d

    def reset_stats(self) -> None:
        self.prefill.reset_stats()
        for eng in self.decodes:
            eng.reset_stats()
        self._seam_deferrals = 0
        self.handoff_count = 0
        self.handoffs_lost = 0
        self.handoffs_replayed = 0
        self.imports_by_shard = {
            f"shard{i}": 0 for i in range(len(self.decodes))}
        self.seam_deferrals_by_shard = {
            f"shard{i}": 0 for i in range(len(self.decodes))}
