"""Paged KV cache — vLLM-style block-table memory management for serving.

The dense per-request cache allocates max_len for every slot; with mixed
request lengths most of it is dead.  Here KV storage is a shared pool of
fixed-size token blocks; each sequence owns a block table (indices into
the pool) that grows on demand and frees on completion — fragmentation-
free reuse across a serving batch, the enabler for continuous batching.

Pure-JAX data plane (scatter on the pool) + a tiny host-side allocator.
Two attention paths read the paged cache:

  * ``paged_decode_attention`` — gathers the sequence's blocks into a
    dense ``[B, max_len, ...]`` buffer, then a dense softmax.  O(context)
    HBM traffic per decode step; kept as the *reference* the Pallas
    kernel is validated against.
  * ``kernels.ops.paged_attention`` — walks the block table inside the
    kernel grid (scalar-prefetch index maps), O(blocks-touched) traffic.
    This is what the continuous-batching engine serves with.

Layout note: pools carry ``num_blocks + 1`` rows.  The last row is a
*trash row* the allocator never hands out; ``append_tokens`` routes
writes from batch slots with no allocated blocks (inactive slots of a
fixed-size serving batch) into it so they can ride in the same scatter
without corrupting live blocks.  Readers never touch it: ``gather_kv``
clamps dead table entries to row 0 and masks by length, and the Pallas
kernel predicates those blocks off entirely.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Iterable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class OutOfBlocksError(RuntimeError):
    """KV block pool exhausted — callers defer admission instead of dying.

    Subclasses RuntimeError so legacy ``except RuntimeError`` sites keep
    working; the serving engine catches this type to hold a request in
    its queue until completions release blocks.
    """


class PagedKVState(NamedTuple):
    k_pool: jnp.ndarray       # [num_blocks + 1, P, KVp, hd] (last = trash)
    v_pool: jnp.ndarray
    block_table: jnp.ndarray  # [B, max_blocks] int32 (-1 = unallocated)
    lengths: jnp.ndarray      # [B] int32 tokens written per sequence


class BlockAllocator:
    """Host-side free-list over the shared pool.

    Radiation hardening adds a *quarantine* lane: a block a scrub pass
    found corrupted is pulled out of service (``quarantine``) and never
    re-enters the free list — ``release`` silently skips it, so every
    existing teardown path stays exact without knowing about upsets.
    The accounting invariant is ``free + live + quarantined ==
    num_blocks`` with ``live`` derived, which the property tests pin
    under random op interleavings.  ``on_release`` (optional) fires once
    per block that actually returns to the free list — the serving
    engine hooks it to retire stale integrity digests no matter which
    path (finalize, shared-index refcount, release_sequence) freed the
    block.
    """

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self.free: List[int] = list(range(num_blocks - 1, -1, -1))
        self.quarantined: set = set()
        self.on_release = None            # callable(block) | None

    def alloc(self) -> int:
        if not self.free:
            raise OutOfBlocksError("KV block pool exhausted")
        return self.free.pop()

    def release(self, blocks) -> None:
        for b in blocks:
            b = int(b)
            if b < 0 or b in self.quarantined:
                continue
            self.free.append(b)
            if self.on_release is not None:
                self.on_release(b)

    def quarantine(self, block: int) -> bool:
        """Take ``block`` out of service; True if newly quarantined."""
        b = int(block)
        if b < 0 or b in self.quarantined:
            return False
        self.quarantined.add(b)
        if b in self.free:                # upset caught while block idle
            self.free.remove(b)
        return True

    @property
    def available(self) -> int:
        return len(self.free)

    @property
    def live(self) -> int:
        return self.num_blocks - len(self.free) - len(self.quarantined)


def init_paged_cache(batch: int, num_blocks: int, block_size: int,
                     kv_heads: int, head_dim: int,
                     dtype=jnp.bfloat16,
                     max_blocks: Optional[int] = None) -> PagedKVState:
    """Pools get one extra trash row (see module docstring).

    ``max_blocks`` bounds the per-sequence table width (defaults to
    ``num_blocks``: any sequence may own the whole pool).  The serving
    engine passes ``ceil(max_len / block_size)`` so the kernel's table
    walk is O(max_len / P), not O(pool size).
    """
    if max_blocks is None:
        max_blocks = num_blocks
    return PagedKVState(
        jnp.zeros((num_blocks + 1, block_size, kv_heads, head_dim), dtype),
        jnp.zeros((num_blocks + 1, block_size, kv_heads, head_dim), dtype),
        -jnp.ones((batch, max_blocks), jnp.int32),
        jnp.zeros((batch,), jnp.int32))


def plan_blocks(table: np.ndarray, alloc: BlockAllocator,
                need_blocks: np.ndarray) -> np.ndarray:
    """Host step: grow each sequence's table row to ``need_blocks[i]``.

    Atomic: the total block need is checked against the allocator before
    anything is taken, so an :class:`OutOfBlocksError` leaks nothing and
    the caller can simply retry later.  Returns a new table array
    (``table`` itself is not mutated).  Counts are *blocks*, not tokens —
    ``ensure_blocks`` does the token division; the serving engine calls
    this directly on its host-side table mirror.
    """
    table = np.asarray(table).copy()
    grows = []
    total = 0
    for i, add in enumerate(np.asarray(need_blocks)):
        need, have = int(add), int((table[i] >= 0).sum())
        if need > table.shape[1]:
            raise OutOfBlocksError(
                f"KV block pool exhausted: sequence {i} needs {need} "
                f"blocks > table width {table.shape[1]}")
        if need > have:
            grows.append((i, have, need))
            total += need - have
    if total > alloc.available:
        raise OutOfBlocksError(
            f"KV block pool exhausted: need {total} blocks, "
            f"{alloc.available} available")
    for i, have, need in grows:
        for j in range(have, need):
            table[i, j] = alloc.alloc()
    return table


def ensure_blocks(state: PagedKVState, alloc: BlockAllocator,
                  new_tokens: np.ndarray) -> PagedKVState:
    """Grow each sequence's table to cover len+new tokens (atomic)."""
    p = state.k_pool.shape[1]
    lengths = np.asarray(state.lengths)
    need = -(-(lengths + np.asarray(new_tokens)) // p)   # blocks, not tokens
    table = plan_blocks(np.asarray(state.block_table), alloc, need)
    return state._replace(block_table=jnp.asarray(table))


def release_sequence(state: PagedKVState, alloc: BlockAllocator,
                     seq: int) -> PagedKVState:
    table = np.asarray(state.block_table).copy()
    alloc.release(table[seq][table[seq] >= 0])
    table[seq] = -1
    lengths = np.asarray(state.lengths).copy()
    lengths[seq] = 0
    return state._replace(block_table=jnp.asarray(table),
                          lengths=jnp.asarray(lengths))


@jax.jit
def append_tokens(state: PagedKVState, k: jnp.ndarray,
                  v: jnp.ndarray) -> PagedKVState:
    """Write one new token per sequence.  k, v: [B, KVp, hd].

    Sequences whose next block is unallocated (inactive slots of a
    fixed-size serving batch) write to the trash row instead and their
    length does not advance — the continuous-batching engine relies on
    this to run one fixed-shape scatter for a partially-occupied batch.
    """
    p = state.k_pool.shape[1]
    trash = state.k_pool.shape[0] - 1
    blk_idx = jnp.minimum(state.lengths // p, state.block_table.shape[1] - 1)
    blk = jnp.take_along_axis(state.block_table, blk_idx[:, None],
                              axis=1)[:, 0]                    # [B]
    active = blk >= 0
    blk = jnp.where(active, blk, trash)
    off = jnp.where(active, state.lengths % p, 0)
    k_pool = state.k_pool.at[blk, off].set(k.astype(state.k_pool.dtype))
    v_pool = state.v_pool.at[blk, off].set(v.astype(state.v_pool.dtype))
    return PagedKVState(k_pool, v_pool, state.block_table,
                        state.lengths + active.astype(jnp.int32))


@jax.jit
def write_prefill(state: PagedKVState, k: jnp.ndarray, v: jnp.ndarray,
                  seq) -> PagedKVState:
    """Paste a prefilled sequence's KV into its blocks in one scatter.

    k, v: [S, KVp, hd] — tokens 0..S-1 of sequence ``seq`` (whose table
    row must already cover ceil(S / P) blocks and whose length restarts
    at S).  The pad tail of the last block is zero-filled; it sits past
    ``lengths[seq]`` so every reader masks it off, and subsequent
    ``append_tokens`` writes land on the exact slots anyway.
    """
    p = state.k_pool.shape[1]
    s = k.shape[0]
    nb = -(-s // p)
    pad = nb * p - s

    def blocked(x):
        x = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))
        return x.reshape(nb, p, *x.shape[1:])
    rows = jnp.maximum(state.block_table[seq, :nb], 0)
    k_pool = state.k_pool.at[rows].set(blocked(k).astype(state.k_pool.dtype))
    v_pool = state.v_pool.at[rows].set(blocked(v).astype(state.v_pool.dtype))
    return PagedKVState(k_pool, v_pool, state.block_table,
                        state.lengths.at[seq].set(s))


@jax.jit
def write_prefill_batch(state: PagedKVState, k: jnp.ndarray,
                        v: jnp.ndarray, admit: jnp.ndarray) -> PagedKVState:
    """Batched :func:`write_prefill`: paste every admitted slot's prefill
    KV in one scatter.

    k, v: [B, S, KVp, hd] — the whole prefill batch; ``admit``: [B] bool.
    Non-admitted rows (occupied slots riding along in the fixed-shape
    prefill bucket, or empty slots) scatter into the trash row; admitted
    rows land in their table blocks and restart at length S.
    """
    p = state.k_pool.shape[1]
    trash = state.k_pool.shape[0] - 1
    b, s = k.shape[:2]
    nb = -(-s // p)
    pad = nb * p - s

    def blocked(x):
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x.reshape(b * nb, p, *x.shape[2:])
    rows = state.block_table[:, :nb]
    rows = jnp.where(admit[:, None] & (rows >= 0), rows, trash).reshape(-1)
    k_pool = state.k_pool.at[rows].set(blocked(k).astype(state.k_pool.dtype))
    v_pool = state.v_pool.at[rows].set(blocked(v).astype(state.v_pool.dtype))
    return PagedKVState(k_pool, v_pool, state.block_table,
                        jnp.where(admit, s, state.lengths))


@jax.jit
def write_prefill_chunk(state: PagedKVState, k: jnp.ndarray, v: jnp.ndarray,
                        seq, start) -> PagedKVState:
    """Paste one *chunk* of a prefill into sequence ``seq``'s blocks.

    k, v: [C, KVp, hd] — tokens ``start .. start+C-1`` of the sequence.
    Both ``start`` and ``C`` must be block-aligned (``% P == 0``), so the
    paste is a whole-block scatter (the Pallas-friendly layout: rows are
    written in full, never read-modify-written) and chunks can land in
    any order without masking.  ``seq`` and ``start`` may be traced —
    this is the jit-safe building block chunked paged prefill and the
    co-processing KV handoff both ride.  The sequence's length advances
    to ``start + C``; callers paste chunks left to right so the final
    chunk leaves the true prefill length behind.  Unallocated table
    entries route to the trash row (same contract as ``append_tokens``).
    """
    p = state.k_pool.shape[1]
    trash = state.k_pool.shape[0] - 1
    c = k.shape[0]
    nb = c // p
    row = jax.lax.dynamic_slice(state.block_table[seq], (start // p,), (nb,))
    rows = jnp.where(row >= 0, row, trash)

    def blocked(x):
        return x.reshape(nb, p, *x.shape[1:])
    k_pool = state.k_pool.at[rows].set(blocked(k).astype(state.k_pool.dtype))
    v_pool = state.v_pool.at[rows].set(blocked(v).astype(state.v_pool.dtype))
    return PagedKVState(k_pool, v_pool, state.block_table,
                        state.lengths.at[seq].set(start + c))


def block_checksums(state: PagedKVState, rows: jnp.ndarray) -> jnp.ndarray:
    """Integrity checksums for pool rows ``rows`` — jit-safe.

    Bit-casts each block's K and V content to unsigned integers and sums
    them mod 2**32, so any single-event upset (one flipped bit anywhere
    in the block) changes the checksum.  Works on plain ``[NB+1, P, KVp,
    hd]`` pools and on the engine's sublayer-stacked ``[S, NB+1, ...]``
    pools; rows index the block axis either way.  Pass the trash row to
    pad ``rows`` to a fixed width — its checksum comes back like any
    other and callers just ignore it, keeping one compiled shape.
    """
    total = None
    for pool in (state.k_pool, state.v_pool):
        x = pool[:, rows] if pool.ndim == 5 else pool[rows]
        nbits = jnp.dtype(pool.dtype).itemsize * 8
        u = jax.lax.bitcast_convert_type(
            x, jnp.uint16 if nbits == 16 else jnp.uint32).astype(jnp.uint32)
        row_axis = 1 if pool.ndim == 5 else 0
        s = jnp.sum(u, axis=tuple(a for a in range(u.ndim) if a != row_axis),
                    dtype=jnp.uint32)
        total = s if total is None else total + s
    return total


def pool_checksums(state: PagedKVState) -> jnp.ndarray:
    """Integrity checksums for *every* block row of the pool at once
    (trash row included) — jit-safe, shape ``[NB+1]``.

    The gather-free sibling of :func:`block_checksums`: one straight
    reduction over each pool with no row-index operand, so XLA emits a
    single pass over memory it was going to read anyway instead of
    materializing a gathered copy.  This is the decode hot path's fused
    verify operand — the host indexes the result by the blocks it
    actually has digests for.
    """
    total = None
    for pool in (state.k_pool, state.v_pool):
        nbits = jnp.dtype(pool.dtype).itemsize * 8
        u = jax.lax.bitcast_convert_type(
            pool, jnp.uint16 if nbits == 16 else jnp.uint32)
        row_axis = 1 if pool.ndim == 5 else 0
        # 16-bit pools accumulate mod 2**16 — a single-event upset flips
        # one bit, shifting the sum by a nonzero power of two either
        # way, and the native-width accumulate skips the elementwise
        # upcast on the decode hot path
        s = jnp.sum(u, axis=tuple(a for a in range(u.ndim) if a != row_axis),
                    dtype=u.dtype).astype(jnp.uint32)
        total = s if total is None else total + s
    return total


def wire_checksum(payload: bytes) -> int:
    """Integrity checksum for a serialized handoff payload — the host
    sibling of :func:`pool_checksums`.

    Same construction, different memory: the buffer is zero-padded to a
    word boundary, viewed as native-width unsigned integers, and summed
    mod 2**32, so any single-event upset on the interconnect (one
    flipped bit anywhere in the payload) changes the sum.  Pure numpy —
    the wire format must be checkable on a host that has no accelerator
    at all (the receiving pod verifies before it ever touches a device).
    """
    pad = (-len(payload)) % 4
    if pad:
        payload = payload + b"\x00" * pad
    words = np.frombuffer(payload, dtype=np.uint32)
    return int(np.sum(words, dtype=np.uint32))


class BlockDigestStore:
    """Host-side registry of *sealed* block checksums.

    A block is sealed once its content is final — the tail block a
    decode step is still appending into stays out until it fills, so
    digests never churn on the hot path.  ``scrub_batch`` hands back up
    to ``budget`` sealed blocks round-robin for a budgeted verify pass;
    the engine wires ``BlockAllocator.on_release`` to :meth:`forget` so
    a freed block's digest dies with it and a recycled block can never
    false-positive against a stale seal.
    """

    def __init__(self):
        self._sums: Dict[int, int] = {}
        self._cursor = 0

    def seal(self, block: int, checksum: int) -> None:
        self._sums[int(block)] = int(checksum)

    def forget(self, block: int) -> None:
        self._sums.pop(int(block), None)

    def get(self, block: int) -> Optional[int]:
        return self._sums.get(int(block))

    def items(self) -> List[Tuple[int, int]]:
        """Snapshot of (block, sealed checksum) pairs — safe to iterate
        while corruption handling forgets entries mid-walk."""
        return list(self._sums.items())

    def __contains__(self, block: int) -> bool:
        return int(block) in self._sums

    def __len__(self) -> int:
        return len(self._sums)

    def scrub_batch(self, budget: int) -> List[int]:
        """Next ``budget`` sealed blocks to verify (round-robin)."""
        if not self._sums or budget <= 0:
            return []
        keys = sorted(self._sums)
        self._cursor %= len(keys)
        out = [keys[(self._cursor + j) % len(keys)]
               for j in range(min(budget, len(keys)))]
        self._cursor = (self._cursor + len(out)) % len(keys)
        return out


class SharedBlockIndex:
    """Content-hashed prefix-block sharing over one allocator's pool.

    A full block of prompt tokens is identified by the *chain digest* of
    its content: ``sha1(parent_digest + tokens.tobytes())``.  Because the
    digest folds in the whole token prefix, two sequences map to the
    same digest exactly when their prompts agree through that block —
    the condition under which their KV is bit-identical and the block
    can be shared read-only.  The index tracks a refcount per registered
    block: the prefilling owner holds one reference, each sharer adds
    one, and the block returns to the allocator only when the last
    reference releases.  Entries leave the index the moment their
    refcount hits zero, so sharing happens across *concurrently live*
    sequences (a common system prompt across a batch) and the allocator
    accounting stays exact — no unreferenced cache to evict.
    """

    ROOT = b""

    def __init__(self, alloc: BlockAllocator):
        self.alloc = alloc
        self._by_digest: Dict[bytes, int] = {}
        self._digest_of: Dict[int, bytes] = {}
        self._refs: Dict[int, int] = {}
        self.hits = 0                     # blocks reused instead of refilled
        self.lookups = 0                  # share attempts (hits + misses)

    @staticmethod
    def chain(parent: bytes, tokens: np.ndarray) -> bytes:
        return hashlib.sha1(parent
                            + np.ascontiguousarray(tokens, np.int32)
                            .tobytes()).digest()

    def lookup(self, digest: bytes) -> Optional[int]:
        return self._by_digest.get(digest)

    def acquire(self, digest: bytes) -> Optional[int]:
        """Take a reference on the block holding ``digest``'s KV."""
        self.lookups += 1
        blk = self._by_digest.get(digest)
        if blk is not None:
            self._refs[blk] += 1
            self.hits += 1
        return blk

    def register(self, digest: bytes, block: int) -> None:
        """Publish a freshly prefilled block (owner's reference)."""
        if digest in self._by_digest:     # raced by an identical prompt:
            return                        # keep the first copy canonical
        self._by_digest[digest] = block
        self._digest_of[block] = digest
        self._refs[block] = self._refs.get(block, 0) + 1

    def release(self, blocks: Iterable[int] = ()) -> List[int]:
        """Drop one reference per block; returns the blocks NOT tracked
        here (still owned solely by the caller) so the caller can hand
        them straight back to the allocator.  Tracked blocks go back to
        the allocator automatically when their last reference drops."""
        untracked: List[int] = []
        for b in blocks:
            b = int(b)
            if b < 0:
                continue
            if b not in self._refs:
                untracked.append(b)
                continue
            self._refs[b] -= 1
            if self._refs[b] <= 0:
                del self._refs[b]
                self._by_digest.pop(self._digest_of.pop(b), None)
                self.alloc.release([b])
        return untracked

    def purge(self, block: int) -> None:
        """Evict a corrupted block from the index unconditionally.

        The block is headed for quarantine, not the free list, so all
        outstanding references are dropped at once — future prompts with
        the same prefix re-prefill a fresh copy instead of sharing the
        upset one.  Refcounts cannot leak: the entry is gone, so every
        holder's eventual ``release`` treats the block as untracked and
        the allocator (already holding it in quarantine) skips it.
        """
        b = int(block)
        if b not in self._refs:
            return
        del self._refs[b]
        self._by_digest.pop(self._digest_of.pop(b), None)


def gather_kv(state: PagedKVState, max_len: int
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Materialize each sequence's KV up to max_len.

    Returns (k [B, max_len, KVp, hd], v likewise, valid [B, max_len]).
    """
    p = state.k_pool.shape[1]
    nb = -(-max_len // p)
    table = jnp.where(state.block_table[:, :nb] >= 0,
                      state.block_table[:, :nb], 0)
    k = state.k_pool[table]                    # [B, nb, P, KVp, hd]
    v = state.v_pool[table]
    b = k.shape[0]
    k = k.reshape(b, nb * p, *k.shape[3:])[:, :max_len]
    v = v.reshape(b, nb * p, *v.shape[3:])[:, :max_len]
    valid = jnp.arange(max_len)[None, :] < state.lengths[:, None]
    return k, v, valid


def paged_decode_attention(q: jnp.ndarray, state: PagedKVState,
                           max_len: int) -> jnp.ndarray:
    """q: [B, KVp, gp, hd] (one token) -> [B, KVp, gp, hd].

    Reference path: gathers the full KV then runs a dense softmax.  The
    serving engine uses the Pallas kernel (``kernels.ops.paged_attention``)
    instead; tests check the two agree.
    """
    import math
    k, v, valid = gather_kv(state, max_len)
    hd = q.shape[-1]
    scores = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgt,btkd->bkgd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
