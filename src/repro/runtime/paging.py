"""Paged KV cache — vLLM-style block-table memory management for serving.

The dense per-request cache allocates max_len for every slot; with mixed
request lengths most of it is dead.  Here KV storage is a shared pool of
fixed-size token blocks; each sequence owns a block table (indices into
the pool) that grows on demand and frees on completion — fragmentation-
free reuse across a serving batch, the enabler for continuous batching.

Pure-JAX data plane (gather/scatter on the pool) + a tiny host-side
allocator; attention against a paged cache gathers the sequence's blocks
then proceeds exactly like the dense path (equivalence is tested).
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PagedKVState(NamedTuple):
    k_pool: jnp.ndarray       # [num_blocks, P, KVp, hd]
    v_pool: jnp.ndarray
    block_table: jnp.ndarray  # [B, max_blocks] int32 (-1 = unallocated)
    lengths: jnp.ndarray      # [B] int32 tokens written per sequence


class BlockAllocator:
    """Host-side free-list over the shared pool."""

    def __init__(self, num_blocks: int):
        self.free: List[int] = list(range(num_blocks - 1, -1, -1))

    def alloc(self) -> int:
        if not self.free:
            raise RuntimeError("KV block pool exhausted")
        return self.free.pop()

    def release(self, blocks) -> None:
        for b in blocks:
            if b >= 0:
                self.free.append(int(b))

    @property
    def available(self) -> int:
        return len(self.free)


def init_paged_cache(batch: int, num_blocks: int, block_size: int,
                     kv_heads: int, head_dim: int,
                     dtype=jnp.bfloat16) -> PagedKVState:
    max_blocks = num_blocks  # upper bound; tables are mostly -1
    return PagedKVState(
        jnp.zeros((num_blocks, block_size, kv_heads, head_dim), dtype),
        jnp.zeros((num_blocks, block_size, kv_heads, head_dim), dtype),
        -jnp.ones((batch, max_blocks), jnp.int32),
        jnp.zeros((batch,), jnp.int32))


def ensure_blocks(state: PagedKVState, alloc: BlockAllocator,
                  new_tokens: np.ndarray) -> PagedKVState:
    """Host step: grow each sequence's table to cover len+new tokens."""
    p = state.k_pool.shape[1]
    table = np.asarray(state.block_table).copy()
    lengths = np.asarray(state.lengths)
    for i, add in enumerate(np.asarray(new_tokens)):
        need = -(-(int(lengths[i]) + int(add)) // p)
        have = int((table[i] >= 0).sum())
        for j in range(have, need):
            table[i, j] = alloc.alloc()
    return state._replace(block_table=jnp.asarray(table))


def release_sequence(state: PagedKVState, alloc: BlockAllocator,
                     seq: int) -> PagedKVState:
    table = np.asarray(state.block_table).copy()
    alloc.release(table[seq][table[seq] >= 0])
    table[seq] = -1
    lengths = np.asarray(state.lengths).copy()
    lengths[seq] = 0
    return state._replace(block_table=jnp.asarray(table),
                          lengths=jnp.asarray(lengths))


@jax.jit
def append_tokens(state: PagedKVState, k: jnp.ndarray,
                  v: jnp.ndarray) -> PagedKVState:
    """Write one new token per sequence.  k, v: [B, KVp, hd]."""
    p = state.k_pool.shape[1]
    blk_idx = state.lengths // p
    blk = jnp.take_along_axis(state.block_table, blk_idx[:, None],
                              axis=1)[:, 0]                    # [B]
    off = state.lengths % p
    k_pool = state.k_pool.at[blk, off].set(k.astype(state.k_pool.dtype))
    v_pool = state.v_pool.at[blk, off].set(v.astype(state.v_pool.dtype))
    return PagedKVState(k_pool, v_pool, state.block_table,
                        state.lengths + 1)


def gather_kv(state: PagedKVState, max_len: int
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Materialize each sequence's KV up to max_len.

    Returns (k [B, max_len, KVp, hd], v likewise, valid [B, max_len]).
    """
    p = state.k_pool.shape[1]
    nb = -(-max_len // p)
    table = jnp.where(state.block_table[:, :nb] >= 0,
                      state.block_table[:, :nb], 0)
    k = state.k_pool[table]                    # [B, nb, P, KVp, hd]
    v = state.v_pool[table]
    b = k.shape[0]
    k = k.reshape(b, nb * p, *k.shape[3:])[:, :max_len]
    v = v.reshape(b, nb * p, *v.shape[3:])[:, :max_len]
    valid = jnp.arange(max_len)[None, :] < state.lengths[:, None]
    return k, v, valid


def paged_decode_attention(q: jnp.ndarray, state: PagedKVState,
                           max_len: int) -> jnp.ndarray:
    """q: [B, KVp, gp, hd] (one token) -> [B, KVp, gp, hd]."""
    import math
    k, v, valid = gather_kv(state, max_len)
    hd = q.shape[-1]
    scores = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgt,btkd->bkgd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
