"""Distributed training loop: jit'd step with explicit shardings,
microbatch gradient accumulation, checkpointing, and fault-tolerant
restart hooks.

The step function is pure pjit: DP gradients reduce over (pod, data),
TP/EP collectives over model, FSDP weight gathers overlap with the layer
scan (XLA schedules the next layer's all-gather against the current
layer's compute).  Partition-aware QAT is just a plan argument — the
same loop trains baseline and MPAI variants.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import sharding as shard
from repro.configs.base import MeshConfig, ModelConfig, ShapeConfig, TrainConfig
from repro.core.partition import PartitionPlan
from repro.models import transformer as T
from repro.optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    step: jnp.ndarray


def build_mesh(mesh_cfg: MeshConfig) -> Mesh:
    devs = np.array(jax.devices())
    need = mesh_cfg.num_devices
    assert devs.size >= need, (devs.size, need)
    return jax.make_mesh(mesh_cfg.shape, mesh_cfg.axes,
                         devices=devs[:need].tolist())


def make_step_fn(cfg: ModelConfig, tc: TrainConfig,
                 plan: Optional[PartitionPlan], tp: int):
    """(state, batch) -> (state, metrics); grad-accum aware."""

    def loss(params, tokens, labels, fe):
        return T.loss_fn(params, cfg, tokens, labels, plan, tp,
                         frontend_embeds=fe)

    def step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        fe = batch.get("frontend_embeds")
        if cfg.grad_accum > 1:
            b = batch["tokens"].shape[0]
            mb = b // cfg.grad_accum
            split = lambda a: a.reshape(cfg.grad_accum, mb, *a.shape[1:])
            toks = split(batch["tokens"])
            labs = split(batch["labels"])
            fes = split(fe) if fe is not None else None

            def micro(carry, inp):
                gsum, lsum = carry
                tk, lb, f = inp
                l, g = jax.value_and_grad(loss)(state.params, tk, lb, f)
                gsum = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(a.dtype), gsum, g)
                return (gsum, lsum + l), None
            acc_dt = jnp.dtype(tc.accum_dtype)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), state.params)
            (gsum, lsum), _ = jax.lax.scan(micro, (g0, 0.0),
                                           (toks, labs, fes))
            grads = jax.tree_util.tree_map(lambda g: g / cfg.grad_accum, gsum)
            l = lsum / cfg.grad_accum
        else:
            l, grads = jax.value_and_grad(loss)(state.params,
                                                batch["tokens"],
                                                batch["labels"], fe)
        params, opt, gnorm = adamw.apply_updates(state.params, grads,
                                                 state.opt, tc)
        return (TrainState(params, opt, state.step + 1),
                {"loss": l, "grad_norm": gnorm})
    return step


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 mesh_cfg: MeshConfig, tc: TrainConfig,
                 plan: Optional[PartitionPlan] = None,
                 mesh: Optional[Mesh] = None):
        self.cfg, self.shape, self.tc, self.plan = cfg, shape, tc, plan
        self.mesh_cfg = mesh_cfg
        self.mesh = mesh if mesh is not None else build_mesh(mesh_cfg)
        self.tp = mesh_cfg.tp

        pshape = jax.eval_shape(partial(T.model_init, cfg=cfg, tp=self.tp),
                                jax.random.PRNGKey(tc.seed))
        self.param_specs = shard.param_specs(cfg, pshape, mesh_cfg)
        opt_specs = adamw.AdamWState(self.param_specs, self.param_specs, P())
        self.state_specs = TrainState(self.param_specs, opt_specs, P())
        self.data_specs = shard.data_specs(cfg, shape, mesh_cfg)

        self.state_shardings = shard.make_shardings(self.mesh,
                                                    self.state_specs)
        data_shardings = shard.make_shardings(self.mesh, self.data_specs)

        step = make_step_fn(cfg, tc, plan, self.tp)
        self.step_fn = jax.jit(
            step,
            in_shardings=(self.state_shardings, data_shardings),
            out_shardings=(self.state_shardings, None),
            donate_argnums=(0,))
        self._init_fn = jax.jit(
            lambda key: self._init_state(key),
            out_shardings=self.state_shardings)

    def _init_state(self, key):
        import jax.numpy as _jnp
        params = T.model_init(key, self.cfg, self.tp)
        return TrainState(params,
                          adamw.init(params, _jnp.dtype(self.tc.opt_dtype)),
                          jnp.zeros((), jnp.int32))

    def init_state(self) -> TrainState:
        with self.mesh:
            return self._init_fn(jax.random.PRNGKey(self.tc.seed))

    def run(self, state: TrainState, data_fn, num_steps: int,
            ckpt=None, log_every: int = 10, on_step=None):
        """data_fn(step) -> batch dict.  Returns (state, history)."""
        history = []
        start = int(state.step)
        for s in range(start, start + num_steps):
            batch = data_fn(s)
            with self.mesh:
                state, metrics = self.step_fn(state, batch)
            if on_step is not None:
                on_step(s, state, metrics)
            if (s + 1) % log_every == 0 or s == start:
                history.append({"step": s + 1,
                                "loss": float(metrics["loss"]),
                                "grad_norm": float(metrics["grad_norm"])})
            if ckpt is not None and (s + 1) % self.tc.checkpoint_every == 0:
                ckpt.save(s + 1, state)
        return state, history
