"""Per-request sampling: greedy / temperature / top-k, inside the fused
decode+sample step.

The continuous-batching engine samples *inside* its jitted decode program
(one dispatch per step, ``[B]`` ints on the wire).  To keep that property
with per-request sampling, every knob is a per-slot array threaded
through the jit boundary:

* ``temperature`` — 0.0 means greedy (argmax), matching the windowed
  baseline bit-for-bit, so all existing goldens hold by default;
* ``top_k`` — 0 means the full vocabulary; otherwise logits outside the
  top-k are masked to ``-inf`` before the categorical draw;
* ``seed`` + per-token step index — the PRNG key for token ``t`` of a
  request is ``fold_in(PRNGKey(seed), t)``.  Keys depend only on the
  request's own seed and its own token index, never on the batch
  composition, so a sampled request produces the *same* tokens whether it
  decodes solo or packed into slots with strangers (mid-decode admission
  cannot perturb it) — the property the engine's output-equivalence
  tests rely on.

The top-k threshold is computed with a full per-row sort: O(V log V) per
step, negligible against the transformer forward on the CPU repro
configs; swap in ``jax.lax.top_k`` if a large-vocab deployment ever
makes this the hot spot.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Dict

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs (defaults = greedy decode)."""
    temperature: float = 0.0
    top_k: int = 0                    # 0 = full vocabulary
    seed: int = 0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    def to_dict(self) -> Dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "SamplingParams":
        return cls(**d)


GREEDY = SamplingParams()


def sample_logits(logits: jnp.ndarray, temps: jnp.ndarray,
                  top_ks: jnp.ndarray, seeds: jnp.ndarray,
                  steps: jnp.ndarray) -> jnp.ndarray:
    """Sample one token per row.  jit-safe; all shapes static.

    logits: [B, V]; temps: [B] float32 (<=0 -> greedy); top_ks: [B] int32
    (0 -> no truncation); seeds/steps: [B] int32 -> per-row key
    ``fold_in(PRNGKey(seed), step)``.  Returns [B] int32.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = logits.astype(jnp.float32)
    v = l.shape[-1]
    # top-k mask: threshold at the k-th largest logit per row
    desc = jnp.sort(l, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(
        desc, jnp.clip(top_ks - 1, 0, v - 1)[:, None], axis=-1)
    truncate = (top_ks[:, None] > 0) & (l < kth)
    scaled = jnp.where(truncate, -jnp.inf, l) / jnp.maximum(
        temps[:, None], 1e-6)

    def row(seed, step, row_logits):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        return jax.random.categorical(key, row_logits).astype(jnp.int32)

    sampled = jax.vmap(row)(seeds, steps, scaled)
    return jnp.where(temps > 0.0, sampled, greedy)
