"""Elastic re-meshing: rebuild a coherent mesh from surviving devices.

After a node failure the device count shrinks; training resumes on the
largest usable (data, model) grid.  The model axis is kept as large a
divisor of the original TP degree as the parameters' head-padding allows
(head padding was computed for the original tp; any divisor of it still
divides the padded head counts), so restored checkpoints reshard without
reshaping.
"""
from __future__ import annotations

from typing import Sequence

from repro.configs.base import MeshConfig


def _divisors_desc(n: int) -> Sequence[int]:
    return sorted({d for d in range(1, n + 1) if n % d == 0}, reverse=True)


def choose_mesh(num_devices: int, prefer_model: int = 16,
                min_data: int = 1) -> MeshConfig:
    """Largest (data, model) grid with model | prefer_model that fits."""
    for model in _divisors_desc(prefer_model):
        if model > num_devices:
            continue
        data = num_devices // model
        if data >= min_data:
            return MeshConfig((data, model), ("data", "model"))
    return MeshConfig((1, 1), ("data", "model"))


def surviving_mesh(mesh_cfg: MeshConfig, lost_devices: int) -> MeshConfig:
    alive = mesh_cfg.num_devices - lost_devices
    assert alive >= 1, "no devices survive"
    return choose_mesh(alive, prefer_model=mesh_cfg.tp)
