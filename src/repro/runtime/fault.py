"""Fault-tolerant training runner: checkpoint/restart with bounded retry.

The contract with 1000+-node reality: any step may raise (device loss,
preemption, network partition).  The runner restores the last committed
checkpoint, optionally rebuilds the mesh from surviving devices
(``elastic.choose_mesh``), re-jits, and replays — the deterministic data
pipeline guarantees the replayed batches are identical.

``FaultInjector`` drives the tests: it raises at scheduled steps to prove
recovery reproduces the uninterrupted run bit-for-bit.

Serving-side faults (router subsystem): onboard accelerators in space see
SEU-style transient upsets — a device drops out, then (usually) comes back
after a scrub/reset.  ``PoolFault`` / ``PoolFaultInjector`` model this at
pool granularity on the router's clock: at ``at_s`` the named pool loses
``lost_profiles`` (or all of them), and recovers after ``duration_s``
unless the fault is permanent (``duration_s=inf``).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.train_loop import Trainer, TrainState


class FaultInjector:
    def __init__(self, fail_at_steps=(), exc=RuntimeError):
        self.fail_at = set(fail_at_steps)
        self.exc = exc

    def __call__(self, step: int, state, metrics):
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise self.exc(f"injected fault at step {step}")


@dataclass(frozen=True)
class PoolFault:
    """One scheduled upset on the router's clock.

    ``kind`` picks the blast radius:

    * ``"pool"`` (default, the original behaviour) — the named pool loses
      ``lost_profiles`` (or everything) and in-flight work fails over.
    * ``"kv_bitflip"`` — a single event upset flips one bit in a live
      paged KV block of the pool's engine; ``seed`` picks the bit.
    * ``"slot_stall"`` — engine slot ``slot`` latches up: the next
      request admitted there makes no decode progress until the fault
      recovers (the watchdog evicts and replays it meanwhile).
    * ``"handoff_loss"`` — the next prefill->decode ``PrefillHandoff``
      payload is dropped at the seam and must be re-requested.
    """
    pool: str
    at_s: float
    lost_profiles: Tuple[str, ...] = ()     # () -> the whole pool drops out
    duration_s: float = math.inf            # finite -> transient (SEU scrub)
    kind: str = "pool"
    slot: int = 0                           # slot_stall target
    seed: int = 0                           # kv_bitflip site selector

    @property
    def transient(self) -> bool:
        return math.isfinite(self.duration_s)


@dataclass(frozen=True)
class PoolFaultEvent:
    kind: str                               # "degrade" | "recover"
    fault: PoolFault
    at_s: float


class PoolFaultInjector:
    """Time-ordered degrade/recover event stream for the serving router.

    ``poll(now)`` returns every event due at or before ``now`` exactly
    once, in time order — the FailoverController consumes them and drives
    pool state + rescheduling.
    """

    def __init__(self, faults: Sequence[PoolFault] = ()):
        self._heap: List[Tuple[float, int, PoolFaultEvent]] = []
        self._n = 0
        for f in faults:
            self.schedule(f)

    def schedule(self, fault: PoolFault) -> None:
        self._push(PoolFaultEvent("degrade", fault, fault.at_s))
        if fault.transient:
            self._push(PoolFaultEvent("recover", fault,
                                      fault.at_s + fault.duration_s))

    def _push(self, ev: PoolFaultEvent) -> None:
        heapq.heappush(self._heap, (ev.at_s, self._n, ev))
        self._n += 1

    def poll(self, now: float) -> List[PoolFaultEvent]:
        due = []
        while self._heap and self._heap[0][0] <= now:
            due.append(heapq.heappop(self._heap)[2])
        return due

    @property
    def pending(self) -> int:
        return len(self._heap)


class FaultTolerantRunner:
    def __init__(self, trainer: Trainer, ckpt: CheckpointManager,
                 max_restarts: int = 3,
                 rebuild: Optional[Callable[[], Trainer]] = None):
        self.trainer = trainer
        self.ckpt = ckpt
        self.max_restarts = max_restarts
        self.rebuild = rebuild
        self.restarts = 0

    def _restore(self) -> TrainState:
        if self.rebuild is not None:            # elastic path: new mesh/jit
            self.trainer = self.rebuild()
        like = jax.eval_shape(self.trainer._init_state,
                              jax.random.PRNGKey(self.trainer.tc.seed))
        state, step = self.ckpt.restore(
            like, shardings=self.trainer.state_shardings)
        return state

    def run(self, state: TrainState, data_fn, num_steps: int,
            on_step=None, log_every: int = 10):
        target = int(state.step) + num_steps
        # Per-step metric records, keyed by step so a restarted segment's
        # replay overwrites (bit-identically, by determinism) instead of
        # duplicating.  The trainer's own segment history used to be the
        # source, but a mid-segment fault discarded everything that
        # segment had logged — steps completed before the last checkpoint
        # silently vanished from the returned history.
        records: dict = {}

        def _observe(s, st, metrics):
            records[s + 1] = {"step": s + 1,
                              "loss": float(metrics["loss"]),
                              "grad_norm": float(metrics["grad_norm"])}
            if on_step is not None:
                on_step(s, st, metrics)

        # always have a step-0 baseline to restart from
        if self.ckpt.latest_step() is None:
            self.ckpt.save(int(state.step), state, blocking=True)
        while int(state.step) < target:
            try:
                state, _ = self.trainer.run(
                    state, data_fn, target - int(state.step),
                    ckpt=self.ckpt, on_step=_observe, log_every=log_every)
            except Exception as e:              # noqa: BLE001 — any step fault
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts") from e
                state = self._restore()
        return state, [records[k] for k in sorted(records)]
