"""ShapeDtypeStruct stand-ins for every lowering target (no allocation)."""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T
from repro.models.frontends import frontend_embeds_spec


def token_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Token positions = seq_len minus the (stub) frontend positions."""
    if cfg.frontend != "none" and shape.kind in ("train", "prefill"):
        return shape.seq_len - cfg.frontend_tokens
    return shape.seq_len


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    b = shape.global_batch
    s = token_len(cfg, shape)
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind == "train":
        out = {"tokens": tok, "labels": tok}
    else:
        out = {"tokens": tok}
    if cfg.frontend != "none" and shape.kind in ("train", "prefill"):
        out["frontend_embeds"] = frontend_embeds_spec(cfg, b)
    return out


def param_structs(cfg: ModelConfig, tp: int, dtype=None):
    shapes = jax.eval_shape(partial(T.model_init, cfg=cfg, tp=tp),
                            jax.random.PRNGKey(0))
    if dtype is None:
        return shapes
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype),
        shapes)


def param_structs_quantized(cfg: ModelConfig, tp: int):
    """Serving structs with the MPAI int8 deployment: every stacked-layer
    weight matrix is a QTensor (int8 values + per-layer-per-channel f32
    scales); embed/head/norms stay bf16.  Halves the resident weight bytes
    of the backbone — the measured §Perf lever on decode cells."""
    import jax.numpy as jnp
    from repro.core.quantization import QTensor
    shapes = param_structs(cfg, tp, jnp.bfloat16)
    QUANTIZABLE = {"wq", "wk", "wv", "wo", "w_in", "w_gate", "w_out",
                   "in_proj", "out_proj", "x_proj",
                   "w_r", "w_k", "w_v", "w_g", "w_o", "w_kc", "w_vc",
                   "w_rc"}    # dt_proj/loras stay float (tiny, fp32 math)

    def q(path, leaf):
        name = ""
        for e in reversed(path):
            if isinstance(e, jax.tree_util.DictKey):
                name = str(e.key)
                break
        if name in QUANTIZABLE and len(leaf.shape) >= 3 and \
                jnp.issubdtype(leaf.dtype, jnp.floating):
            scale_shape = (leaf.shape[0],) + (1,) * (len(leaf.shape) - 2) \
                + (leaf.shape[-1],)
            return QTensor(jax.ShapeDtypeStruct(leaf.shape, jnp.int8),
                           jax.ShapeDtypeStruct(scale_shape, jnp.float32))
        return leaf
    shapes["layers"] = jax.tree_util.tree_map_with_path(q, shapes["layers"])
    return shapes


def cache_structs(cfg: ModelConfig, shape: ShapeConfig, tp: int):
    return jax.eval_shape(
        partial(T.init_cache, cfg, shape.global_batch, shape.seq_len, tp))


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, tp: int
                 ) -> Tuple[Dict, object]:
    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return {"tokens": tok}, cache_structs(cfg, shape, tp)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, tp: int) -> Dict:
    """Everything the cell's step function consumes, as structs."""
    out = {"batch": batch_specs(cfg, shape)}
    if shape.kind == "decode":
        out["batch"], out["cache"] = decode_specs(cfg, shape, tp)
    return out
