"""Serving launcher: batched requests against an MPAI-partitioned model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
        --plan mpai --requests 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import qat
from repro.core.partition import PartitionPlan
from repro.models import transformer as T
from repro.runtime.serve import BatchingServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--plan", default="mpai", choices=["bf16", "mpai"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    plan = (qat.serve_plan(PartitionPlan.mpai(cfg.num_layers))
            if args.plan == "mpai" else None)
    srv = BatchingServer(params, cfg, plan=plan, max_batch=args.max_batch,
                         prompt_len=16, max_len=16 + args.max_new)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        srv.submit(Request(i, rng.integers(
            0, cfg.vocab_size, rng.integers(2, 16)).astype(np.int32),
            max_new=args.max_new))
    t0 = time.perf_counter()
    windows = 0
    while srv.queue:
        srv.flush()
        windows += 1
    dt = time.perf_counter() - t0
    tok = sum(r.output.shape[0] for r in srv.done.values())
    print(f"served {len(srv.done)} requests / {tok} tokens in {windows} "
          f"windows, {dt:.2f}s ({tok/dt:.1f} tok/s on this host)")


if __name__ == "__main__":
    main()
