"""Serving launcher: batched requests against an MPAI-partitioned model,
through the ``repro.serving`` facade.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
        --plan mpai --requests 16

Throughput note: tokens/s is reported *decode-only* (sampled decode
tokens over wall time inside decode steps), the same definition
``benchmarks/decode_bench.py`` uses — the old launcher divided total
tokens (prompt handling included) by end-to-end wall time, which mixed
prefill-window idle time into the number.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.serving import FleetSpec, PoolSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--plan", default="mpai", choices=["bf16", "mpai"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a Chrome trace_event JSON of the run "
                         "(open in Perfetto / chrome://tracing)")
    args = ap.parse_args()

    spec = FleetSpec(
        pools=[PoolSpec("serve", ("tpu_v5e_bf16",), backend="engine",
                        capacity=1, max_window=args.max_batch,
                        max_wait_s=0.0, max_slots=args.max_batch,
                        prompt_len=16, max_new=args.max_new,
                        plan=args.plan if args.plan == "mpai" else None)],
        workload="transformer", arch=args.arch, smoke=args.smoke,
        seq_len=16)
    client = spec.build()
    if args.trace:
        client.enable_tracing()

    rng = np.random.default_rng(0)
    vocab = client.engines["serve"].cfg.vocab_size
    handles = [client.submit(
        rng.integers(0, vocab, rng.integers(2, 16)).astype(np.int32),
        slo="offline", max_new=args.max_new)
        for _ in range(args.requests)]
    client.drain()

    pool = client.telemetry["pools"]["serve"]
    served = sum(h.admitted and not h.telemetry["dropped"]
                 for h in handles)
    print(f"served {served} requests / {pool['tokens_generated']} tokens "
          f"in {pool['batches']} batches, {pool['busy_s']:.2f}s busy "
          f"({pool['decode_tokens_per_s']:.1f} decode tok/s, "
          f"occupancy p50 {pool['slot_occupancy']['p50']})")
    if args.trace:
        from repro.obs import export_chrome_trace
        trace = export_chrome_trace(client, args.trace)
        print(f"wrote {len(trace['traceEvents'])} trace events to "
              f"{args.trace}")


if __name__ == "__main__":
    main()
