import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract memory / cost / collective statistics.

The two lines above MUST stay the first statements in this file: jax locks
the device count at first backend init, and only the dry-run is allowed to
fake 512 host devices (smoke tests and benchmarks see the real 1).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--plan mpai]
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shard
from repro.configs import SHAPES, cells, get_config
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.core.partition import PartitionPlan
from repro.core import qat
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh, production_mesh_config
from repro.models import transformer as T
from repro.optim import adamw
from repro.roofline import RooflineReport, model_flops, parse_collectives
from repro.runtime.train_loop import TrainState, make_step_fn


def _make_plan(cfg: ModelConfig, plan_name: str, kind: str):
    if plan_name == "bf16":
        return None
    if plan_name == "mpai":
        base = PartitionPlan.mpai(cfg.num_layers,
                                  split=max(1, cfg.num_layers
                                            - T.pattern_period(cfg)))
        return qat.train_plan(base) if kind == "train" else qat.serve_plan(base)
    raise ValueError(plan_name)


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, mesh_cfg,
               plan_name: str = "bf16", tc: TrainConfig = None):
    tp = mesh_cfg.tp
    plan = _make_plan(cfg, plan_name, shape.kind)
    pspecs_tree = S.param_structs(cfg, tp)
    param_sp = shard.param_specs(cfg, pspecs_tree, mesh_cfg)
    param_sh = shard.make_shardings(mesh, param_sp)
    data_sp = shard.data_specs(cfg, shape, mesh_cfg)
    data_sh = shard.make_shardings(mesh, data_sp)

    if shape.kind == "train":
        tc = tc or TrainConfig()
        step = make_step_fn(cfg, tc, plan, tp)
        opt_dt = jnp.dtype(tc.opt_dtype)
        opt_tree = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, opt_dt if jnp.issubdtype(s.dtype, jnp.floating)
                else s.dtype), pspecs_tree)
        state_struct = TrainState(
            pspecs_tree,
            adamw.AdamWState(opt_tree, opt_tree,
                             jax.ShapeDtypeStruct((), jnp.int32)),
            jax.ShapeDtypeStruct((), jnp.int32))
        state_sp = TrainState(
            param_sp, adamw.AdamWState(param_sp, param_sp, P()), P())
        state_sh = shard.make_shardings(mesh, state_sp)
        fn = jax.jit(step, in_shardings=(state_sh, data_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
        args = (state_struct, S.batch_specs(cfg, shape))

    elif shape.kind == "prefill":
        if plan is not None and any(s.policy.mode == "quant"
                                    for s in plan.segments):
            bparams = S.param_structs_quantized(cfg, tp)
            param_sp = shard.param_specs(cfg, bparams, mesh_cfg)
            param_sh = shard.make_shardings(mesh, param_sp)
        else:
            bparams = S.param_structs(cfg, tp, jnp.bfloat16)

        def prefill_fn(params, batch):
            return T.forward(params, cfg, batch["tokens"], plan, tp,
                             frontend_embeds=batch.get("frontend_embeds")
                             ).logits
        fn = jax.jit(prefill_fn, in_shardings=(param_sh, data_sh),
                     out_shardings=shard.make_shardings(
                         mesh, shard.logits_spec(shape, mesh_cfg)))
        args = (bparams, S.batch_specs(cfg, shape))

    else:  # decode
        if plan is not None and any(s.policy.mode == "quant"
                                    for s in plan.segments):
            # MPAI deployment: pre-quantized int8 backbone weights
            bparams = S.param_structs_quantized(cfg, tp)
            param_sp = shard.param_specs(cfg, bparams, mesh_cfg)
            param_sh = shard.make_shardings(mesh, param_sp)
        else:
            bparams = S.param_structs(cfg, tp, jnp.bfloat16)
        batch_struct, cache_struct = S.decode_specs(cfg, shape, tp)
        cache_sp = shard.cache_specs(cfg, cache_struct, shape, mesh_cfg)
        cache_sh = shard.make_shardings(mesh, cache_sp)

        def decode_fn(params, tokens, cache):
            out = T.decode_step(params, cfg, tokens, cache, plan, tp)
            return out.logits, out.cache
        fn = jax.jit(decode_fn,
                     in_shardings=(param_sh, data_sh["tokens"], cache_sh),
                     out_shardings=(None, cache_sh), donate_argnums=(2,))
        args = (bparams, batch_struct["tokens"], cache_struct)

    with mesh:
        lowered = fn.lower(*args)
    return lowered


def probe_costs(cfg: ModelConfig, shape: ShapeConfig, mesh, mesh_cfg,
                plan_name: str = "bf16", tc: TrainConfig = None):
    """Exact per-layer HLO costs via 1- vs 2-superblock unrolled probes.

    XLA's cost analysis counts a while/scan body ONCE, so the scanned-layer
    production program underreports flops/bytes/collectives by the trip
    count.  The probes unroll every scan (layers, kv chunks, SSM chunks,
    grad accum) at reduced depth; differencing two depths isolates the
    exact per-superblock cost, and probe(1) carries the embed/head/optimizer
    constant term:  total = probe1 + (n_super - 1) * (probe2 - probe1).
    """
    period = T.pattern_period(cfg)
    n_super = cfg.num_layers // period

    def one(n):
        pcfg = cfg.with_(num_layers=n * period, scan_layers=False,
                         grad_accum=1, scan_chunk=2048)
        lowered = lower_cell(pcfg, shape, mesh, mesh_cfg, plan_name, tc=tc)
        compiled = lowered.compile()
        ca = compiled.cost_analysis() or {}
        coll = parse_collectives(compiled.as_text())
        return (float(ca.get("flops", 0.0)),
                float(ca.get("bytes accessed", 0.0)),
                coll.total_bytes, coll)

    f1 = one(1)
    if n_super == 1:
        return {"flops": f1[0], "hlo_bytes": f1[1],
                "collective_bytes": f1[2],
                "collective_bytes_by_kind": f1[3].bytes_by_kind,
                "collective_counts": f1[3].count_by_kind}
    f2 = one(2)
    per = [b - a for a, b in zip(f1[:3], f2[:3])]
    tot = [a + (n_super - 1) * p for a, p in zip(f1[:3], per)]
    kinds = {k: f1[3].bytes_by_kind.get(k, 0.0)
             + (n_super - 1) * (f2[3].bytes_by_kind.get(k, 0.0)
                                - f1[3].bytes_by_kind.get(k, 0.0))
             for k in set(f1[3].bytes_by_kind) | set(f2[3].bytes_by_kind)}
    counts = {k: f1[3].count_by_kind.get(k, 0)
              + (n_super - 1) * (f2[3].count_by_kind.get(k, 0)
                                 - f1[3].count_by_kind.get(k, 0))
              for k in set(f1[3].count_by_kind) | set(f2[3].count_by_kind)}
    return {"flops": tot[0], "hlo_bytes": tot[1], "collective_bytes": tot[2],
            "collective_bytes_by_kind": kinds, "collective_counts": counts}


def analyze(lowered, cfg: ModelConfig, shape: ShapeConfig, mesh_cfg,
            compile_: bool = True):
    t0 = time.time()
    stats = {"arch": cfg.name, "shape": shape.name,
             "mesh": "x".join(map(str, mesh_cfg.shape))}
    coll = parse_collectives(lowered.as_text())     # pre-SPMD (usually empty)
    if compile_:
        compiled = lowered.compile()
        stats["compile_s"] = round(time.time() - t0, 1)
        # collectives live in the optimized (post-SPMD) HLO, per partition
        coll = parse_collectives(compiled.as_text())
        ca = compiled.cost_analysis() or {}
        ma = compiled.memory_analysis()
        stats["flops"] = float(ca.get("flops", 0.0))
        stats["hlo_bytes"] = float(ca.get("bytes accessed", 0.0))
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            stats[attr] = getattr(ma, attr, None)
    stats["collective_bytes"] = coll.total_bytes
    stats["collective_counts"] = coll.count_by_kind
    stats["collective_bytes_by_kind"] = coll.bytes_by_kind
    stats["roofline"] = roofline_row(cfg, shape, mesh_cfg, stats)
    return stats


def roofline_row(cfg, shape, mesh_cfg, stats):
    rep = RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_cfg.shape,
        chips=mesh_cfg.num_devices,
        hlo_flops=stats.get("flops", 0.0),
        hlo_bytes=stats.get("hlo_bytes", 0.0),
        collective_bytes=stats.get("collective_bytes", 0.0),
        model_flops=model_flops(cfg, shape))
    return rep.row()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--archs", default=None,
                    help="comma-separated arch filter for --all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--plan", default="bf16", choices=["bf16", "mpai"])
    ap.add_argument("--kv-cache", default="bfloat16",
                    choices=["bfloat16", "int8"],
                    help="KV cache dtype for decode cells (§Perf C2)")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the per-layer cost probes (roofline will "
                         "underreport scanned-layer costs)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_cfg = production_mesh_config(multi_pod=args.multi_pod)
    print(f"mesh: {mesh_cfg.shape} {mesh_cfg.axes} "
          f"({mesh_cfg.num_devices} devices)")

    if args.all:
        todo = [(a, s) for a, s, _ in cells()]
        if args.archs:
            keep = set(args.archs.split(","))
            todo = [(a, s) for a, s in todo if a in keep]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        todo = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape_name in todo:
        cfg = get_config(arch)
        if args.kv_cache != "bfloat16":
            cfg = cfg.with_(kv_cache_dtype=args.kv_cache)
        shape = SHAPES[shape_name]
        tag = f"{arch}__{shape_name}__{'multi' if args.multi_pod else 'single'}"
        if args.plan != "bf16":
            tag += f"__{args.plan}"
        if args.kv_cache != "bfloat16":
            tag += "__kv8"
        print(f"=== {tag} ===", flush=True)
        t0 = time.time()
        try:
            lowered = lower_cell(cfg, shape, mesh, mesh_cfg, args.plan)
            print(f"  lowered in {time.time() - t0:.1f}s", flush=True)
            stats = analyze(lowered, cfg, shape, mesh_cfg,
                            compile_=not args.no_compile)
            if not (args.no_probe or args.no_compile):
                t1 = time.time()
                probe = probe_costs(cfg, shape, mesh, mesh_cfg, args.plan)
                probe["probe_s"] = round(time.time() - t1, 1)
                stats["scanned_raw"] = {
                    k: stats.get(k) for k in
                    ("flops", "hlo_bytes", "collective_bytes")}
                stats.update(probe)
                stats["roofline"] = roofline_row(cfg, shape, mesh_cfg, stats)
            r = stats["roofline"]
            print(f"  compile {stats.get('compile_s', '-')}s | "
                  f"flops/dev {stats.get('flops', 0) / 1e9:.1f}G | "
                  f"coll {stats['collective_bytes'] / 1e9:.2f}GB | "
                  f"dominant {r['dominant']} | "
                  f"terms c/m/x = {r['compute_ms']}/{r['memory_ms']}/"
                  f"{r['collective_ms']} ms", flush=True)
            if stats.get("temp_size_in_bytes") is not None:
                print(f"  temp/dev {stats['temp_size_in_bytes'] / 1e9:.2f}GB "
                      f"args/dev {stats['argument_size_in_bytes'] / 1e9:.2f}GB",
                      flush=True)
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(stats, f, indent=1)
        except Exception as e:                       # noqa: BLE001
            failures.append((tag, repr(e)))
            traceback.print_exc()
    print(f"\n{len(todo) - len(failures)}/{len(todo)} cells OK")
    for tag, err in failures:
        print(f"FAIL {tag}: {err[:200]}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
