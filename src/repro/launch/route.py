"""Fleet-router demo: SLO-aware dispatch across accelerator pools with a
mid-run fault and online failover.

    PYTHONPATH=src python -m repro.launch.route                 # vision fleet
    PYTHONPATH=src python -m repro.launch.route --lm            # + TPU pod LM
    PYTHONPATH=src python -m repro.launch.route --execute-lm --smoke \
        --arch qwen3-14b                                        # real decode

The vision section routes a mixed-SLO UrsoNet workload across three
pools (two DPU+VPU boards, one EdgeTPU+CPU sidecar); at ``--fault-at``
board-b takes an SEU and drops out for ``--fault-duration`` seconds —
its queued and in-flight requests are rescheduled over the survivors.
The LM sections route the same SLO machinery over TPU v5e operating
points (cost-model pools, or a real BatchingServer with ``--execute-lm``).
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core.cost_model import (layer_costs_from_convspecs,
                                   transformer_layer_costs)
from repro.models.cnn import ursonet_table1_layers
from repro.router import (AcceleratorPool, CostModelExecutor,
                          FailoverController, Router, RouterRequest,
                          SLO_CLASSES, ServerExecutor, SLOClass)
from repro.runtime.fault import PoolFault, PoolFaultInjector


def open_loop(router: Router, fc: FailoverController, classes, weights,
              rate_hz: float, n_requests: int, seed: int = 0,
              dt: float = 0.002, payload_fn=None):
    """Drive Poisson open-loop traffic through the router until drained."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += rng.exponential(1.0 / rate_hz)
        slo = classes[rng.choice(len(classes), p=weights)]
        reqs.append(RouterRequest(i, slo, t,
                                  payload=payload_fn(rng) if payload_fn
                                  else None))
    t, i = 0.0, 0
    while i < len(reqs) or router.outstanding or fc.pending_faults:
        t += dt
        fc.poll(t)
        while i < len(reqs) and reqs[i].arrival_s <= t:
            router.submit(reqs[i], t)
            i += 1
        router.step(t)
        if t > 600.0:          # safety net: never loop forever
            break
    return t


def vision_section(args) -> dict:
    layers = layer_costs_from_convspecs(ursonet_table1_layers())
    pools = [
        AcceleratorPool("board-a", ("mpsoc_dpu", "myriadx_vpu"),
                        CostModelExecutor(layers), capacity=2, max_window=4),
        AcceleratorPool("board-b", ("mpsoc_dpu", "myriadx_vpu"),
                        CostModelExecutor(layers), capacity=2, max_window=4),
        AcceleratorPool("sidecar", ("edge_tpu", "cortex_a53"),
                        CostModelExecutor(layers), capacity=1, max_window=2),
    ]
    router = Router(layers, pools,
                    accuracy_penalty={"mpsoc_dpu": 0.05})  # QAT'd backbone
    n_before = len(router.frontier)
    # board-b drops out entirely; half a scrub later the sidecar loses its
    # Edge TPU — the only pool with that profile, so the frontier itself
    # shrinks until the scrub completes
    inj = PoolFaultInjector([
        PoolFault("board-b", at_s=args.fault_at,
                  duration_s=args.fault_duration),
        PoolFault("sidecar", at_s=args.fault_at + args.fault_duration / 2,
                  lost_profiles=("edge_tpu",),
                  duration_s=args.fault_duration),
    ])
    fc = FailoverController(router, inj)
    classes = [SLO_CLASSES["downlink-critical"],
               SLO_CLASSES["realtime-tracking"],
               SLO_CLASSES["background-science"],
               SLO_CLASSES["bulk-reprocess"]]
    open_loop(router, fc, classes, [0.2, 0.3, 0.3, 0.2],
              rate_hz=args.rate, n_requests=args.requests, seed=args.seed)
    snap = router.telemetry.snapshot()
    snap["frontier_plans_initial"] = n_before
    snap["frontier_plans_final"] = len(router.frontier)
    snap["frontier_trace"] = [
        {"t": round(t, 3), "plans": n} for t, n in fc.frontier_sizes]
    snap["fault_events"] = [
        {"kind": e.kind, "pool": e.fault.pool, "at_s": e.at_s}
        for e in fc.events]
    return snap


def lm_section(args) -> dict:
    from repro.configs import get_config
    cfg = get_config(args.arch, smoke=True)
    layers = transformer_layer_costs(cfg, seq_len=args.seq)
    cuts = list(range(1, cfg.num_layers))
    pools = [
        AcceleratorPool("pod-int8", ("tpu_v5e_int8",),
                        CostModelExecutor(layers), capacity=4, max_window=8),
        AcceleratorPool("pod-bf16", ("tpu_v5e_bf16",),
                        CostModelExecutor(layers), capacity=4, max_window=8),
        AcceleratorPool("pod-mixed", ("tpu_v5e_int8", "tpu_v5e_bf16"),
                        CostModelExecutor(layers), capacity=4, max_window=8),
    ]
    interactive = SLOClass("lm-interactive", max_latency_s=0.05,
                           max_accuracy_penalty=0.02, priority=1)
    batch = SLOClass("lm-batch", max_latency_s=1.0, max_energy_j=2.0)
    router = Router(layers, pools, cut_candidates=cuts,
                    accuracy_penalty={"tpu_v5e_int8": 0.015})
    inj = PoolFaultInjector([PoolFault("pod-int8", at_s=args.fault_at,
                                       duration_s=args.fault_duration)])
    fc = FailoverController(router, inj)
    open_loop(router, fc, [interactive, batch], [0.5, 0.5],
              rate_hz=args.rate * 4, n_requests=args.requests,
              seed=args.seed)
    return router.telemetry.snapshot()


def lm_execute_section(args) -> dict:
    """Real decode: an LM pool backed by the continuous-batching engine
    (or the windowed baseline with ``--windowed-lm``), driven through
    the router via its non-blocking step() executor."""
    import jax

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.runtime.serve import BatchingServer, ContinuousBatchingEngine

    cfg = get_config(args.arch, smoke=True)
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    layers = transformer_layer_costs(cfg, seq_len=16)
    max_len = 16 + max(args.max_new, 2)    # warm-up request uses max_new=2
    srv = None
    if not args.windowed_lm:
        try:
            srv = ContinuousBatchingEngine(params, cfg, max_slots=4,
                                           prompt_len=16, max_len=max_len,
                                           block_size=8)
        except ValueError:        # hybrid/SSM stack: paged decode is attn-only
            pass
    if srv is None:
        srv = BatchingServer(params, cfg, max_batch=4, prompt_len=16,
                             max_len=max_len)
    # warm up the jitted prefill/decode so the one-off compile time does
    # not land in the first routed batch's latency telemetry
    from repro.runtime.serve import Request as ServeRequest
    srv.submit(ServeRequest(-1, np.array([1, 2], np.int32), max_new=2))
    srv.flush()
    executor = ServerExecutor(srv, max_new=args.max_new)
    pools = [AcceleratorPool("lm-real", ("tpu_v5e_bf16",), executor,
                             capacity=1, max_window=4, max_wait_s=0.0)]
    executor.counters = pools[0].counters      # tokens/s + occupancy
    relaxed = SLOClass("lm-offline", max_latency_s=120.0)
    router = Router(layers, pools)
    fc = FailoverController(router, PoolFaultInjector())
    rng = np.random.default_rng(args.seed)

    def prompt(r):
        return r.integers(0, cfg.vocab_size, int(r.integers(2, 16))
                          ).astype(np.int32)

    open_loop(router, fc, [relaxed], [1.0], rate_hz=50.0,
              n_requests=min(args.requests, 16), seed=args.seed, dt=0.05,
              payload_fn=prompt)
    snap = router.telemetry.snapshot()
    snap["generated_tokens"] = sum(r.output.shape[0]
                                   for rid, r in srv.done.items()
                                   if rid >= 0)
    return snap


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--rate", type=float, default=40.0,
                    help="open-loop arrival rate, requests/s")
    ap.add_argument("--fault-at", type=float, default=3.0)
    ap.add_argument("--fault-duration", type=float, default=4.0,
                    help="SEU scrub window; inf-like values = permanent")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lm", action="store_true",
                    help="also route an LM workload over TPU v5e pools")
    ap.add_argument("--execute-lm", action="store_true",
                    help="route real decodes through an LM server pool")
    ap.add_argument("--windowed-lm", action="store_true",
                    help="--execute-lm with the windowed BatchingServer "
                         "baseline instead of the continuous engine")
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")   # accepted for parity
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--json", action="store_true",
                    help="print raw JSON only (for scripting)")
    args = ap.parse_args()

    report = {"vision": vision_section(args)}
    if args.lm:
        report["lm_costmodel"] = lm_section(args)
    if args.execute_lm:
        report["lm_real"] = lm_execute_section(args)

    if args.json:
        print(json.dumps(report, indent=2))
        return
    v = report["vision"]
    print(json.dumps(report, indent=2))
    total = v["completed"] + v["dropped"]
    print(f"\nvision fleet: {v['admitted']} admitted / {v['rejected']} "
          f"rejected; {v['completed']} completed, {v['violations']} SLO "
          f"violations ({v['dropped']} dropped); {v['failovers']} failover, "
          f"{v['reschedules']} reschedules "
          f"(frontier {v['frontier_plans_initial']} -> "
          f"{v['frontier_plans_final']} plans)")
    assert total == v["admitted"], "router lost requests"


if __name__ == "__main__":
    main()
