"""Fleet-router demo: SLO-aware dispatch across accelerator pools with a
mid-run fault and online failover — all through the ``repro.serving``
facade (declarative :class:`FleetSpec` + :class:`ServingClient`).

    PYTHONPATH=src python -m repro.launch.route                 # vision fleet
    PYTHONPATH=src python -m repro.launch.route --lm            # + TPU pod LM
    PYTHONPATH=src python -m repro.launch.route --execute-lm --smoke \
        --arch qwen3-14b                                        # real decode

The vision section routes a mixed-SLO UrsoNet workload across three
pools (two DPU+VPU boards, one EdgeTPU+CPU sidecar); at ``--fault-at``
board-b takes an SEU and drops out for ``--fault-duration`` seconds —
its queued and in-flight requests are rescheduled over the survivors.
The LM sections route the same SLO machinery over TPU v5e operating
points (cost-model pools, or the continuous-batching engine behind an
engine-backed pool with ``--execute-lm``).
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.router import SLO_CLASSES
from repro.serving import FaultSpec, FleetSpec, PoolSpec
from repro.serving.traffic import open_loop


def vision_fleet_spec(faults=()) -> FleetSpec:
    """The canonical three-pool MPAI vision fleet — two DPU+VPU boards
    and an EdgeTPU+CPU sidecar with a QAT'd-backbone accuracy prior.
    ``benchmarks/router_bench.py`` reuses this spec (faults differ per
    scenario), so the demo and the benchmark measure one fleet."""
    return FleetSpec(
        pools=[
            PoolSpec("board-a", ("mpsoc_dpu", "myriadx_vpu"),
                     capacity=2, max_window=4),
            PoolSpec("board-b", ("mpsoc_dpu", "myriadx_vpu"),
                     capacity=2, max_window=4),
            PoolSpec("sidecar", ("edge_tpu", "cortex_a53"),
                     capacity=1, max_window=2),
        ],
        workload="ursonet",
        accuracy_penalty={"mpsoc_dpu": 0.05},      # QAT'd backbone
        faults=list(faults))


def vision_section(args) -> dict:
    # board-b drops out entirely; half a scrub later the sidecar loses
    # its Edge TPU — the only pool with that profile, so the frontier
    # itself shrinks until the scrub completes
    spec = vision_fleet_spec(faults=[
        FaultSpec("board-b", at_s=args.fault_at,
                  duration_s=args.fault_duration),
        FaultSpec("sidecar", at_s=args.fault_at + args.fault_duration / 2,
                  lost_profiles=("edge_tpu",),
                  duration_s=args.fault_duration),
    ])
    client = spec.build()
    n_before = len(client.router.frontier)
    classes = [SLO_CLASSES["downlink-critical"],
               SLO_CLASSES["realtime-tracking"],
               SLO_CLASSES["background-science"],
               SLO_CLASSES["bulk-reprocess"]]
    open_loop(client, classes, [0.2, 0.3, 0.3, 0.2],
              rate_hz=args.rate, n_requests=args.requests, seed=args.seed)
    snap = client.telemetry
    snap["frontier_plans_initial"] = n_before
    snap["frontier_plans_final"] = len(client.router.frontier)
    snap["frontier_trace"] = [
        {"t": round(t, 3), "plans": n}
        for t, n in client.failover.frontier_sizes]
    snap["fault_events"] = [
        {"kind": e.kind, "pool": e.fault.pool, "at_s": e.at_s}
        for e in client.failover.events]
    return snap


def lm_section(args) -> dict:
    from repro.configs import get_config
    cfg = get_config(args.arch, smoke=True)
    spec = FleetSpec(
        pools=[
            PoolSpec("pod-int8", ("tpu_v5e_int8",),
                     capacity=4, max_window=8),
            PoolSpec("pod-bf16", ("tpu_v5e_bf16",),
                     capacity=4, max_window=8),
            PoolSpec("pod-mixed", ("tpu_v5e_int8", "tpu_v5e_bf16"),
                     capacity=4, max_window=8),
        ],
        workload="transformer", arch=args.arch, seq_len=args.seq,
        cut_candidates=list(range(1, cfg.num_layers)),
        accuracy_penalty={"tpu_v5e_int8": 0.015},
        slos=[dict(name="lm-interactive", max_latency_s=0.05,
                   max_accuracy_penalty=0.02, priority=1),
              dict(name="lm-batch", max_latency_s=1.0, max_energy_j=2.0)],
        faults=[FaultSpec("pod-int8", at_s=args.fault_at,
                          duration_s=args.fault_duration)])
    client = spec.build()
    classes = [client.resolve_slo("lm-interactive"),
               client.resolve_slo("lm-batch")]
    open_loop(client, classes, [0.5, 0.5], rate_hz=args.rate * 4,
              n_requests=args.requests, seed=args.seed)
    return client.telemetry


def lm_execute_section(args) -> dict:
    """Real decode: an LM pool backed by the continuous-batching engine
    (or the windowed baseline with ``--windowed-lm``), routed through
    the facade."""
    spec = FleetSpec(
        pools=[PoolSpec("lm-real", ("tpu_v5e_bf16",),
                        backend="windowed" if args.windowed_lm
                        else "engine",
                        capacity=1, max_window=4, max_wait_s=0.0,
                        max_slots=4, prompt_len=16,
                        max_new=args.max_new)],
        workload="transformer", arch=args.arch, seq_len=16,
        slos=[dict(name="lm-offline", max_latency_s=120.0)])
    client = spec.build()           # build() warms jit out of telemetry
    vocab = client.engines["lm-real"].cfg.vocab_size

    def prompt(r):
        return r.integers(0, vocab, int(r.integers(2, 16))
                          ).astype(np.int32)

    handles = open_loop(client, [client.resolve_slo("lm-offline")], [1.0],
                        rate_hz=50.0,
                        n_requests=min(args.requests, 16),
                        seed=args.seed, dt=0.05, payload_fn=prompt)
    snap = client.telemetry
    snap["generated_tokens"] = sum(len(h.tokens) for h in handles)
    return snap


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--rate", type=float, default=40.0,
                    help="open-loop arrival rate, requests/s")
    ap.add_argument("--fault-at", type=float, default=3.0)
    ap.add_argument("--fault-duration", type=float, default=4.0,
                    help="SEU scrub window; inf-like values = permanent")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lm", action="store_true",
                    help="also route an LM workload over TPU v5e pools")
    ap.add_argument("--execute-lm", action="store_true",
                    help="route real decodes through an LM server pool")
    ap.add_argument("--windowed-lm", action="store_true",
                    help="--execute-lm with the windowed baseline "
                         "instead of the continuous engine")
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")   # accepted for parity
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--json", action="store_true",
                    help="print raw JSON only (for scripting)")
    args = ap.parse_args()

    report = {"vision": vision_section(args)}
    if args.lm:
        report["lm_costmodel"] = lm_section(args)
    if args.execute_lm:
        report["lm_real"] = lm_execute_section(args)

    if args.json:
        print(json.dumps(report, indent=2))
        return
    v = report["vision"]
    print(json.dumps(report, indent=2))
    total = v["completed"] + v["dropped"]
    print(f"\nvision fleet: {v['admitted']} admitted / {v['rejected']} "
          f"rejected; {v['completed']} completed, {v['violations']} SLO "
          f"violations ({v['dropped']} dropped); {v['failovers']} failover, "
          f"{v['reschedules']} reschedules "
          f"(frontier {v['frontier_plans_initial']} -> "
          f"{v['frontier_plans_final']} plans)")
    assert total == v["admitted"], "router lost requests"


if __name__ == "__main__":
    main()
