"""Orbit-controller demo: ride a sunlit/eclipse power cycle live.

    PYTHONPATH=src python -m repro.launch.orbit                  # capped
    PYTHONPATH=src python -m repro.launch.orbit --uncontrolled   # baseline
    PYTHONPATH=src python -m repro.launch.orbit --json

The canonical vision fleet (``launch/route.py``) serves a mixed-SLO
open-loop trace whose arrivals straddle an eclipse.  With the
controller attached (:class:`~repro.orbit.OrbitSpec`), the energy
bucket drains through the eclipse, the fleet flips to energy-first plan
selection, offline-class work parks until sunlight returns, and the
autoscaler grows/shrinks the DPU+VPU board family against queue depth —
cumulative fleet ``energy_j`` stays inside the orbit-average budget.
Uncontrolled, the same trace burns through the budget mid-eclipse.

``benchmarks/orbit_bench.py`` reuses :func:`run_eclipse_scenario`
verbatim, so the demo and the benchmark measure one scenario — same
pattern as ``route.py`` / ``router_bench.py``.

Everything runs on the fleet's virtual clock (cost-model pools), so a
given seed reproduces the identical trace, budget, and scale events on
any machine.
"""
from __future__ import annotations

import argparse
import json

from repro.launch.route import vision_fleet_spec
from repro.orbit import OrbitSpec, PhaseSpec, ScalingPolicy, budget_j
from repro.router import SLO_CLASSES, select_plan
from repro.serving.traffic import open_loop

# offline-heavy mix with a critical floor: the deferrable classes ride
# the bucket, downlink-critical keeps dispatching through the eclipse
MIX = [("downlink-critical", 0.2), ("background-science", 0.5),
       ("bulk-reprocess", 0.3)]


def eclipse_orbit_spec(demand_w: float, *, sunlit_s: float = 1.0,
                       eclipse_s: float = 4.0, sunlit_margin: float = 1.3,
                       eclipse_frac: float = 0.1, bucket_s: float = 1.0,
                       scaling: ScalingPolicy = None) -> OrbitSpec:
    """Size an orbit around the fleet's nominal demand (watts): harvest
    ``sunlit_margin`` x demand in sunlight, ``eclipse_frac`` x demand in
    shadow, with a battery holding ``bucket_s`` seconds of demand."""
    return OrbitSpec(
        phases=[PhaseSpec("sunlit", sunlit_s, sunlit_margin * demand_w),
                PhaseSpec("eclipse", eclipse_s, eclipse_frac * demand_w)],
        bucket_j=bucket_s * demand_w,
        scaling=scaling)


def mix_demand_w(client, rate_hz: float, mix=MIX) -> float:
    """The fleet's nominal electrical demand for an arrival mix: each
    class priced at the plan nominal dispatch would pick for it (not the
    frontier's global minimum — critical classes buy fast, dear plans,
    and sizing the orbit below their real draw would put the controller
    in eclipse posture even in full sunlight)."""
    per_req = 0.0
    for name, w in mix:
        plan = select_plan(client.router.frontier, SLO_CLASSES[name],
                           latency_headroom=client.router.latency_headroom)
        if plan is not None:
            per_req += w * plan.energy_j
    return rate_hz * per_req


def run_eclipse_scenario(n_requests: int = 300, rate_hz: float = 60.0,
                         seed: int = 0, controlled: bool = True,
                         scale: bool = True,
                         trace_path: str = None) -> dict:
    """One eclipse transition, controller on or off; returns the report.

    Both variants are scored against the *same* orbit-average budget
    (battery at t=0 plus harvest up to each run's own end time), so
    ``energy_ratio <= 1`` means the fleet lived within the orbit.
    """
    client = vision_fleet_spec().build()
    demand_w = mix_demand_w(client, rate_hz)
    scaling = (ScalingPolicy(template="board-a", min_pools=1, max_pools=3,
                             queue_high=6, queue_low=0, cooldown_s=0.1)
               if scale else None)
    ospec = eclipse_orbit_spec(demand_w, scaling=scaling)
    ctrl = ospec.attach(client) if controlled else None
    if trace_path:
        client.enable_tracing()

    classes = [SLO_CLASSES[n] for n, _ in MIX]
    weights = [w for _, w in MIX]
    handles = open_loop(client, classes, weights, rate_hz=rate_hz,
                        n_requests=n_requests, seed=seed)
    for _ in range(300):                 # idle tail: let clones retire
        client.step()
    t_end = client.now

    snap = client.telemetry
    spent = snap["energy_j"]
    budget = budget_j(ospec.profile(), ospec.initial_frac * ospec.bucket_j,
                      0.0, t_end)
    admitted = max(snap["admitted"], 1)
    report = {
        "scenario": ("orbit_eclipse_on" if controlled
                     else "orbit_eclipse_off"),
        "controlled": controlled,
        "requests": n_requests,
        "rate_hz": rate_hz,
        "t_end_s": round(t_end, 3),
        "energy_j": spent,
        "budget_j": round(budget, 4),
        "energy_ratio": round(spent / budget, 4),
        "orbit_average_w": round(ospec.profile().orbit_average_w, 6),
        "admitted": snap["admitted"],
        "completed": snap["completed"],
        "rejected": snap["rejected"],
        "dropped": snap["dropped"],
        "violations": snap["violations"],
        "violation_rate": round(snap["violations"] / admitted, 4),
        "deferred": snap["energy_deferred"],
        "energy_rejected": snap["energy_rejected"],
        "pools_added": snap["pools_added"],
        "pools_retired": snap["pools_retired"],
        "unresolved_handles": sum(not h.done for h in handles),
    }
    if ctrl is not None:
        report["controller"] = ctrl.report()
    if trace_path:
        from repro.obs import export_chrome_trace
        trace = export_chrome_trace(client, trace_path)
        report["trace_events"] = len(trace["traceEvents"])
        report["trace_path"] = str(trace_path)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=300)
    ap.add_argument("--rate", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--uncontrolled", action="store_true",
                    help="baseline: same trace without the controller")
    ap.add_argument("--no-scale", action="store_true",
                    help="energy cap only, no autoscaler")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a Chrome trace_event JSON of the run — "
                         "pool lanes, orbit phases, counter tracks "
                         "(open in Perfetto / chrome://tracing)")
    args = ap.parse_args()

    report = run_eclipse_scenario(n_requests=args.requests,
                                  rate_hz=args.rate, seed=args.seed,
                                  controlled=not args.uncontrolled,
                                  scale=not args.no_scale,
                                  trace_path=args.trace)
    print(json.dumps(report, indent=2))
    if not args.json:
        word = "inside" if report["energy_ratio"] <= 1.0 else "OVER"
        print(f"\n{report['scenario']}: spent {report['energy_j']:.3f} J "
              f"of a {report['budget_j']:.3f} J orbit budget "
              f"({report['energy_ratio']:.2f}x — {word}); "
              f"{report['deferred']} deferred, "
              f"{report['violations']} violations, "
              f"{report['pools_added']} pools added / "
              f"{report['pools_retired']} retired")


if __name__ == "__main__":
    main()
