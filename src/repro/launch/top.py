"""Fleet health plane: a ``top``-style text dashboard over the SLO engine.

    PYTHONPATH=src python -m repro.launch.top                # live frames
    PYTHONPATH=src python -m repro.launch.top --every 0.5
    PYTHONPATH=src python -m repro.launch.top --json         # final report

Drives the canonical vision fleet (``launch/route.py``) through the
eclipse power cycle (``launch/orbit.py``) with an :class:`SLOSpec`
attached, rendering a frame every ``--every`` *virtual* seconds: mode
and battery, per-class golden signals (TTFT / ITL / queue wait / e2e),
per-objective burn rates and error budgets, and the firing alerts.

Everything runs on the fleet's virtual clock — frames are paced by
simulated time, never wall-clock sleeps, so a seed reproduces the
identical frame sequence on any machine.  :func:`render` is a pure
``client -> str`` function; point it at any live ``ServingClient``
(orbit controller and SLO engine optional) to get the same view.
"""
from __future__ import annotations

import argparse
import json

from repro.launch.orbit import MIX, eclipse_orbit_spec, mix_demand_w
from repro.launch.route import vision_fleet_spec
from repro.obs import SLOObjective, SLOSpec
from repro.router import SLO_CLASSES
from repro.serving.traffic import poisson_arrivals

_BAR_W = 16


def _bar(frac: float, width: int = _BAR_W) -> str:
    frac = min(max(frac, 0.0), 1.0)
    fill = int(round(frac * width))
    return "#" * fill + "." * (width - fill)


def _ms(hist: dict, key: str = "p99") -> str:
    v = hist.get(key)
    return "     -" if not hist.get("count") or v is None else f"{v * 1e3:6.1f}"


def render(client) -> str:
    """One dashboard frame for any live fleet — pure, no side effects."""
    snap = client.telemetry
    lines = []

    # -- header: clock, mode, battery, fleet-level counters ------------
    ctrl = getattr(client, "controller", None)
    head = f"t={client.now:8.3f}s  pools={len(client.router.pools)}"
    if ctrl is not None:
        frac = ctrl.bucket.level_j / ctrl.bucket.capacity_j
        head += (f"  mode={ctrl.mode:<8s}  battery [{_bar(frac)}] "
                 f"{100 * frac:5.1f}%")
        if ctrl.storm:
            head += "  STORM"
    lines.append(head)
    lines.append(f"admitted={snap['admitted']}  completed={snap['completed']}"
                 f"  rejected={snap['rejected']}  dropped={snap['dropped']}"
                 f"  violations={snap['violations']}"
                 f"  queue={snap['queue_depth']}"
                 f"  energy={snap['energy_j']:.2f}J")

    # -- golden signals per SLO class ----------------------------------
    lines.append("")
    lines.append(f"{'class':<20s} {'done':>6s} {'drop':>5s} {'viol':>5s} "
                 f"{'ttft p99':>8s} {'itl p99':>8s} {'wait p99':>8s} "
                 f"{'e2e p99':>8s}  (ms)")
    by_class = snap["slis"]["by_class"]
    for name in sorted(by_class):
        s = by_class[name]
        lines.append(f"{name:<20s} {s['completed']:>6d} {s['dropped']:>5d} "
                     f"{s['violated']:>5d} {_ms(s['ttft_s']):>8s} "
                     f"{_ms(s['itl_s']):>8s} {_ms(s['queue_wait_s']):>8s} "
                     f"{_ms(s['e2e_s']):>8s}")
    if not by_class:
        lines.append("(no completions yet)")

    # -- SLO objectives: burn rates and error budgets ------------------
    engine = getattr(client, "slo_engine", None)
    if engine is not None:
        lines.append("")
        lines.append(f"{'objective':<34s} {'burn 1x':>8s} {'burn 5x':>8s} "
                     f"{'budget':>18s}  state")
        for o in engine.objectives(client.now):
            state = ("PAGE" if o["page"]
                     else "warn" if o["warn"] else "ok")
            rem = o["budget_remaining"]
            name = f"{o['slo_class']}/{o['objective']}"
            lines.append(f"{name:<34s} {o['burn_fast']:>8.2f} "
                         f"{o['burn_slow']:>8.2f} "
                         f"[{_bar(rem)}] {100 * rem:4.0f}%  {state}")

    # -- firing alerts -------------------------------------------------
    alerts = snap["alerts"]
    if alerts["firing"]:
        lines.append("")
        for a in alerts["firing"]:
            lines.append(f"!! {a['severity'].upper():<4s} {a['reason']} "
                         f"class={a['slo_class']} "
                         f"burn={a['burn_fast']:.1f}/{a['burn_slow']:.1f} "
                         f"since t={a['t_fired']:.3f}s")
    return "\n".join(lines)


def health_slo_spec() -> SLOSpec:
    """Objectives for the demo mix, tight enough that the eclipse's
    deferral backlog visibly burns budget on the offline classes."""
    return SLOSpec(objectives=[
        SLOObjective("downlink-critical", p99_e2e_s=0.5,
                     availability=0.999),
        SLOObjective("background-science", p99_e2e_s=2.0,
                     availability=0.99),
        SLOObjective("bulk-reprocess", availability=0.95),
    ], fast_window_s=1.0, slow_window_s=5.0, page_burn=10.0,
        warn_burn=2.0, min_events=5)


def run_dashboard(n_requests: int = 300, rate_hz: float = 60.0,
                  seed: int = 0, every_s: float = 1.0,
                  emit=print) -> dict:
    """The eclipse scenario with frames emitted on the virtual clock."""
    spec = vision_fleet_spec()
    spec.slo = health_slo_spec()
    client = spec.build()
    eclipse_orbit_spec(mix_demand_w(client, rate_hz)).attach(client)

    classes = [SLO_CLASSES[n] for n, _ in MIX]
    weights = [w for _, w in MIX]
    arrivals = poisson_arrivals(classes, weights, rate_hz, n_requests,
                                seed=seed)
    i, frames, next_frame = 0, 0, 0.0
    while i < len(arrivals) or client.outstanding or client.pending_faults:
        client.advance()
        while i < len(arrivals) and arrivals[i][0] <= client.now:
            at, slo, payload = arrivals[i]
            client.submit(payload, slo=slo, arrival=at)
            i += 1
        client.pump()
        if client.now >= next_frame:
            emit(render(client))
            emit("")
            frames += 1
            next_frame = client.now + every_s
        if client.now > 600.0:           # safety net: never loop forever
            break
    for _ in range(300):                 # idle tail: drain + age alerts
        client.step()
    emit(render(client))
    report = client.slo_engine.report()
    report["frames"] = frames + 1
    report["t_end_s"] = round(client.now, 3)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=300)
    ap.add_argument("--rate", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--every", type=float, default=1.0, metavar="VIRT_S",
                    help="frame period in virtual seconds")
    ap.add_argument("--json", action="store_true",
                    help="suppress frames, print the final SLO report")
    args = ap.parse_args(argv)

    emit = (lambda *_: None) if args.json else print
    report = run_dashboard(n_requests=args.requests, rate_hz=args.rate,
                           seed=args.seed, every_s=args.every, emit=emit)
    if args.json:
        print(json.dumps(report, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
