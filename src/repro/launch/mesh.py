"""Mesh construction.  Functions, not module constants — importing this
module never touches jax device state (jax locks the device count on
first backend init, and only dryrun.py is allowed to fake 512 devices)."""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import MeshConfig, MULTI_POD, SINGLE_POD


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def production_mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD if multi_pod else SINGLE_POD


def make_mesh_from_config(mesh_cfg: MeshConfig) -> Mesh:
    devs = np.array(jax.devices())
    assert devs.size >= mesh_cfg.num_devices, (
        f"need {mesh_cfg.num_devices} devices, have {devs.size}")
    return jax.make_mesh(mesh_cfg.shape, mesh_cfg.axes,
                         devices=devs[:mesh_cfg.num_devices].tolist())


def make_local_mesh(model: int = 1) -> Mesh:
    """Whatever this host has: (n/model, model) data x model grid."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    return jax.make_mesh((n // model, model), ("data", "model"))
