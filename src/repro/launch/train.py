"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --shape train_4k --steps 100 [--smoke] [--plan mpai] \
        [--mesh local|single_pod|multi_pod] [--ckpt-dir DIR]

On real hardware ``--mesh single_pod/multi_pod`` expects the process to
see the pod's devices (jax.distributed.initialize on each host).  On this
container use ``--smoke --mesh local`` for a real training run, or the
dry-run entry point for the production meshes.
"""
from __future__ import annotations

import argparse
import tempfile

from repro.checkpoint.manager import CheckpointManager
from repro.configs import SHAPES, get_config
from repro.configs.base import MeshConfig, ShapeConfig, TrainConfig
from repro.core import qat
from repro.core.partition import PartitionPlan
from repro.data.pipeline import lm_batch
from repro.models.frontends import synthetic_frontend_embeds
from repro.runtime.fault import FaultTolerantRunner
from repro.runtime.train_loop import Trainer


def build(args):
    cfg = get_config(args.arch, smoke=args.smoke)
    if args.shape in SHAPES:
        shape = SHAPES[args.shape]
    else:
        seq, batch = map(int, args.shape.split("x"))
        shape = ShapeConfig("custom", seq, batch, "train")
    if args.smoke:
        shape = ShapeConfig("smoke", min(shape.seq_len, 128),
                            min(shape.global_batch, 8), "train")
    if args.mesh == "local":
        import jax
        n = len(jax.devices())
        mesh_cfg = MeshConfig((n, 1), ("data", "model"))
    elif args.mesh == "single_pod":
        mesh_cfg = MeshConfig((16, 16), ("data", "model"))
    else:
        mesh_cfg = MeshConfig((2, 16, 16), ("pod", "data", "model"))
    plan = None
    if args.plan == "mpai":
        plan = qat.train_plan(PartitionPlan.mpai(cfg.num_layers))
    tc = TrainConfig(total_steps=args.steps, checkpoint_every=args.ckpt_every)
    return cfg, shape, mesh_cfg, plan, tc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--plan", default="bf16", choices=["bf16", "mpai"])
    ap.add_argument("--mesh", default="local",
                    choices=["local", "single_pod", "multi_pod"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg, shape, mesh_cfg, plan, tc = build(args)
    print(f"training {cfg.name} ({cfg.param_count()/1e6:.1f}M params) "
          f"shape={shape.name} mesh={mesh_cfg.shape} plan={args.plan}")
    trainer = Trainer(cfg, shape, mesh_cfg, tc, plan=plan)
    state = trainer.init_state()
    ckpt = CheckpointManager(args.ckpt_dir or
                             tempfile.mkdtemp(prefix="repro_ckpt_"),
                             keep=tc.keep_checkpoints)
    runner = FaultTolerantRunner(trainer, ckpt)

    def data(step):
        batch = lm_batch(cfg, shape, step, seed=tc.seed)
        if cfg.frontend != "none":
            batch["frontend_embeds"] = synthetic_frontend_embeds(
                cfg, shape.global_batch, seed=step)
        return batch

    state, hist = runner.run(state, data, args.steps,
                             log_every=max(args.steps // 20, 1))
    for h in hist:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.2f}")


if __name__ == "__main__":
    main()
