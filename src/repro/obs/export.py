"""Trace export: spans -> JSONL and Chrome ``trace_event`` JSON.

The Chrome format (one JSON object with a ``traceEvents`` list) loads
directly into Perfetto / ``chrome://tracing``:

* one *process* lane per pool (stage pools like ``lm.prefill``
  included), plus a ``fleet`` lane for router-level events;
* one *thread* row per stage inside each pool lane (queue / serve /
  admit / prefill_chunk / decode_step / handoff / import), so the
  co-processing pipeline reads left-to-right like the MPAI block
  diagram;
* orbit phases (sunlit/eclipse) as *async* spans on the fleet lane,
  with dispatch-mode changes as instant markers;
* the fleet time-series as counter tracks (queue depth, battery
  fraction, decode tokens/s).

All timestamps are the fleet's virtual clock in microseconds — the unit
the format requires — so a 2 ms tick renders as 2000 us regardless of
how long the host actually took.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

_FLEET = "fleet"


def _lane_ids(tracer) -> Dict[str, int]:
    """Stable pid assignment: fleet first, then pools sorted by name."""
    pools = sorted({sp.pool for sp in tracer.spans if sp.pool is not None})
    lanes = {_FLEET: 0}
    for i, p in enumerate(pools):
        lanes[p] = i + 1
    return lanes


def chrome_trace(tracer, timeseries=None, profile=None,
                 t_end: Optional[float] = None, slo=None) -> Dict:
    """Build the Chrome ``trace_event`` dict from a
    :class:`~repro.obs.trace.Tracer` (plus, optionally, the fleet
    time-series and the orbit power profile for phase lanes, and the
    :class:`~repro.obs.slo.SLOEngine` for burn-rate counter tracks)."""
    lanes = _lane_ids(tracer)
    events: List[Dict] = []
    tids: Dict[tuple, int] = {}

    def tid_of(pid: int, stage: str) -> int:
        key = (pid, stage)
        if key not in tids:
            tids[key] = sum(1 for k in tids if k[0] == pid) + 1
            events.append({"ph": "M", "pid": pid, "tid": tids[key],
                           "name": "thread_name",
                           "args": {"name": stage}})
        return tids[key]

    for name, pid in lanes.items():
        events.append({"ph": "M", "pid": pid, "tid": 0,
                       "name": "process_name", "args": {"name": name}})
        events.append({"ph": "M", "pid": pid, "tid": 0,
                       "name": "process_sort_index",
                       "args": {"sort_index": pid}})

    latest = 0.0
    for sp in tracer.spans:
        pid = lanes.get(sp.pool, 0)
        t1 = sp.t1 if sp.t1 is not None else sp.t0
        latest = max(latest, t1)
        args = {k: v for k, v in sp.attrs.items()}
        if sp.rid is not None:
            args["rid"] = sp.rid
        ev = {"name": sp.stage, "cat": sp.stage,
              "pid": pid, "tid": tid_of(pid, sp.stage),
              "ts": round(sp.t0 * 1e6, 3), "args": args}
        if t1 > sp.t0:
            ev["ph"] = "X"
            ev["dur"] = round((t1 - sp.t0) * 1e6, 3)
        else:                                   # instant marker
            ev["ph"] = "i"
            ev["s"] = "p"
        events.append(ev)

    end = t_end if t_end is not None else latest
    if profile is not None and end > 0:
        # orbit phases as async spans on the fleet lane: walk the cyclic
        # profile from t=0 to the end of the trace
        t, k = 0.0, 0
        while t < end:
            ph = profile.phase_at(t)
            t1 = min(t + ph.duration_s, end)
            events.append({"ph": "b", "cat": "orbit", "id": k,
                           "name": ph.name, "pid": 0, "tid": 0,
                           "ts": round(t * 1e6, 3),
                           "args": {"power_w": ph.power_w}})
            events.append({"ph": "e", "cat": "orbit", "id": k,
                           "name": ph.name, "pid": 0, "tid": 0,
                           "ts": round(t1 * 1e6, 3), "args": {}})
            t, k = t1, k + 1

    if timeseries is not None and len(timeseries):
        rates = timeseries.tokens_per_s()
        for i, s in enumerate(timeseries.samples):
            ts = round(s.t * 1e6, 3)
            events.append({"ph": "C", "pid": 0, "tid": 0, "ts": ts,
                           "name": "queue_depth",
                           "args": {"queued": s.queue_depth}})
            events.append({"ph": "C", "pid": 0, "tid": 0, "ts": ts,
                           "name": "decode_tokens_per_s",
                           "args": {"tok/s": round(rates[i - 1], 2)
                                    if i else 0.0}})
            if s.bucket_frac is not None:
                events.append({"ph": "C", "pid": 0, "tid": 0, "ts": ts,
                               "name": "bucket_frac",
                               "args": {"frac": round(s.bucket_frac, 4)}})
            events.append({"ph": "C", "pid": 0, "tid": 0, "ts": ts,
                           "name": "alerts_firing",
                           "args": {"firing": getattr(s, "alerts", 0)}})

    if slo is not None:
        # SLO engine counter tracks: worst fast-window burn rate and the
        # tightest objective's budget remaining, from the per-tick ring
        for t, worst_burn, _, budget_min in slo.history:
            ts = round(t * 1e6, 3)
            events.append({"ph": "C", "pid": 0, "tid": 0, "ts": ts,
                           "name": "slo_burn_fast",
                           "args": {"burn": round(worst_burn, 3)}})
            events.append({"ph": "C", "pid": 0, "tid": 0, "ts": ts,
                           "name": "slo_budget_min",
                           "args": {"frac": round(budget_min, 4)}})

    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"source": "repro.obs flight recorder",
                          "spans": len(tracer.spans),
                          "dropped_spans": tracer.dropped}}


def export_chrome_trace(client, path, t_end: Optional[float] = None) -> Dict:
    """Write ``client``'s flight-recorder state as Chrome trace JSON.

    Pulls the tracer, the time-series, and (when an orbit controller is
    attached) the power profile off the client, so launch demos and
    benchmarks are a one-liner.  Returns the trace dict."""
    ctrl = getattr(client, "controller", None)
    profile = None
    if ctrl is not None and getattr(ctrl, "spec", None) is not None:
        prof_fn = getattr(ctrl.spec, "profile", None)
        profile = prof_fn() if callable(prof_fn) else None
    trace = chrome_trace(client.tracer, timeseries=client.timeseries,
                         profile=profile,
                         t_end=client.now if t_end is None else t_end,
                         slo=getattr(client, "slo_engine", None))
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace


def export_spans_jsonl(client, path) -> int:
    """Write the client's spans as JSONL; returns the span count."""
    return client.tracer.to_jsonl(path)
