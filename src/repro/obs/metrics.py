"""Metrics exporters: Prometheus text format + ``SLO_report.json``.

The fleet has no HTTP server (and fleetlint would rightly object to one
inside the virtual-clock world), so the Prometheus side is an
*endpoint-less dump*: :func:`prometheus_text` renders the current
telemetry snapshot — fleet counters, per-pool counters, golden-signal
SLI quantiles, and (when an :class:`~repro.obs.slo.SLOEngine` is
attached) per-objective burn rates, budget remaining, and firing
alerts — in the text exposition format, ready to be written to a file
a node_exporter textfile collector (or a test) can pick up.

:func:`slo_report` is the judgment artifact CI uploads: the SLOSpec,
per-objective evaluation, SLI summaries, alert state and history, and
the fleet time-series summary, all JSON-serializable.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple


def _escape(value) -> str:
    return str(value).replace("\\", r"\\").replace('"', r"\"")


def _render(name: str, mtype: str, help_text: str,
            samples: List[Tuple[Dict[str, str], float]],
            lines: List[str]) -> None:
    if not samples:
        return
    lines.append(f"# HELP {name} {help_text}")
    lines.append(f"# TYPE {name} {mtype}")
    for labels, value in samples:
        label_s = ""
        if labels:
            inner = ",".join(f'{k}="{_escape(v)}"'
                             for k, v in labels.items())
            label_s = "{" + inner + "}"
        lines.append(f"{name}{label_s} {value}")


_FLEET_COUNTERS = ("admitted", "rejected", "completed", "violations",
                   "dropped", "failovers", "reschedules", "retries",
                   "watchdog_trips", "bitflips_detected",
                   "blocks_quarantined", "handoffs_replayed",
                   "energy_deferred", "energy_rejected", "pools_added",
                   "pools_retired")
_POOL_COUNTERS = ("dispatched", "completed", "decode_tokens",
                  "prefill_tokens", "evicted", "watchdog_trips")
_SLI_SIGNALS = ("ttft_s", "itl_s", "queue_wait_s", "e2e_s")


def prometheus_text(client) -> str:
    """Render the client's telemetry as Prometheus text exposition."""
    tel = client.router.telemetry
    snap = tel.snapshot()
    lines: List[str] = []

    _render("repro_fleet_events_total", "counter",
            "Fleet lifecycle counters by event.",
            [({"event": k}, snap[k]) for k in _FLEET_COUNTERS
             if k in snap], lines)
    _render("repro_fleet_drops_total", "counter",
            "Dropped requests by reason.",
            [({"reason": k}, v)
             for k, v in sorted(snap["drops_by_reason"].items())], lines)
    _render("repro_fleet_queue_depth", "gauge",
            "Requests queued across the fleet.",
            [({}, snap["queue_depth"])], lines)
    _render("repro_fleet_energy_joules", "gauge",
            "Cumulative fleet energy.", [({}, snap["energy_j"])], lines)

    pool_samples = {c: [] for c in _POOL_COUNTERS}
    for pool, counters in sorted(snap["pools"].items()):
        for c in _POOL_COUNTERS:
            if c in counters:
                pool_samples[c].append(({"pool": pool}, counters[c]))
    for c in _POOL_COUNTERS:
        _render(f"repro_pool_{c}_total", "counter",
                f"Per-pool {c} counter.", pool_samples[c], lines)

    # golden-signal SLI quantiles per scope
    slis = snap["slis"]
    scopes = [({"scope": "fleet"}, slis["fleet"])]
    scopes += [({"scope": "class", "name": k}, v)
               for k, v in sorted(slis["by_class"].items())]
    scopes += [({"scope": "pool", "name": k}, v)
               for k, v in sorted(slis["by_pool"].items())]
    for signal in _SLI_SIGNALS:
        samples = []
        for labels, scope in scopes:
            hist = scope[signal]
            if not hist["count"]:
                continue
            for q in ("p50", "p99"):
                samples.append((dict(labels, quantile=q), hist[q]))
        _render(f"repro_sli_{signal[:-2]}_seconds", "gauge",
                f"Golden-signal {signal} quantiles per scope.",
                samples, lines)

    engine = getattr(client, "slo_engine", None)
    if engine is not None:
        objectives = engine.objectives()
        base = [({"slo_class": o["slo_class"], "objective": o["objective"]},
                 o) for o in objectives]
        _render("repro_slo_burn_rate", "gauge",
                "Fast-window error-budget burn rate per objective.",
                [(lbl, o["burn_fast"]) for lbl, o in base], lines)
        _render("repro_slo_burn_rate_slow", "gauge",
                "Slow-window error-budget burn rate per objective.",
                [(lbl, o["burn_slow"]) for lbl, o in base], lines)
        _render("repro_slo_budget_remaining", "gauge",
                "Fraction of the error budget left per objective.",
                [(lbl, o["budget_remaining"]) for lbl, o in base], lines)
    alerts = snap["alerts"]
    _render("repro_alerts_firing", "gauge",
            "Currently firing SLO alerts.",
            [({"reason": a["reason"], "slo_class": a["slo_class"],
               "severity": a["severity"]}, 1)
             for a in alerts["firing"]] or [({}, 0)], lines)
    _render("repro_alerts_fired_total", "counter",
            "Cumulative alerts fired by severity.",
            [({"severity": "page"}, alerts["pages_fired"]),
             ({"severity": "warn"}, alerts["warns_fired"])], lines)
    return "\n".join(lines) + "\n"


def export_prometheus(client, path: str) -> str:
    """Write :func:`prometheus_text` to ``path``; returns the text."""
    text = prometheus_text(client)
    with open(path, "w") as fh:
        fh.write(text)
    return text


def slo_report(client, t_end: Optional[float] = None) -> Dict:
    """The CI judgment artifact: spec, objectives, SLIs, alerts,
    time-series summary.  JSON-serializable; works without an engine
    attached (``slo`` is then None)."""
    tel = client.router.telemetry
    engine = getattr(client, "slo_engine", None)
    report = {
        "t": round(client.now if t_end is None else t_end, 6),
        "slo": engine.report() if engine is not None else None,
        "telemetry": tel.snapshot(),
    }
    timeseries = getattr(client, "timeseries", None)
    if timeseries is not None:
        report["timeseries"] = timeseries.summary()
    return report


def export_slo_report(client, path: str,
                      t_end: Optional[float] = None) -> Dict:
    """Write :func:`slo_report` to ``path`` as JSON; returns the dict."""
    report = slo_report(client, t_end=t_end)
    with open(path, "w") as fh:
        json.dump(report, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return report
