"""Fleet time-series: a bounded ring buffer sampled on the virtual clock.

Telemetry snapshots answer "where did the run end up"; this answers
"what did the fleet look like *during* the run" — the signal the orbit
report, the autoscaler tests, and the Chrome-trace counter lanes all
want.  :class:`FleetTimeSeries` is sampled from
``ServingClient.advance`` every clock tick (optionally decimated with
``interval_s``), holds at most ``maxlen`` samples (a ring: old samples
age out, the recorder never grows unbounded on long runs), and derives
rates (tokens/s) from cumulative counters at read time so decimation
never biases them.

Each sample is one small tuple-backed row::

    t            virtual time of the sample
    decode_tokens  cumulative fleet decode tokens (rate derivable)
    queue_depth  fleet queued requests at this instant
    load         queued + in-flight
    occupancy    mean engine slot occupancy (0 for cost-model fleets)
    bucket_frac  orbit battery fraction (None when no controller)
    pools        live pool count (autoscaler growth/retirement visible)
    mode         dispatch mode ("nominal"/"conserve"/"critical")
    alerts       SLO alerts firing at sample time (repro.obs.slo)

``decode_tokens`` is a *sanitized* cumulative: per-pool counters are
differentiated before summing, so counters leaving ``telemetry.pools``
(retirement history compaction) can never step the fleet total backward
and spike ``tokens_per_s`` negative.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional


@dataclass(frozen=True)
class Sample:
    t: float
    decode_tokens: int
    queue_depth: int
    load: int
    occupancy: float
    bucket_frac: Optional[float]
    pools: int
    mode: str
    alerts: int = 0                      # SLO alerts firing at sample time

    def to_dict(self) -> Dict:
        return {"t": round(self.t, 6), "decode_tokens": self.decode_tokens,
                "queue_depth": self.queue_depth, "load": self.load,
                "occupancy": round(self.occupancy, 4),
                "bucket_frac": (None if self.bucket_frac is None
                                else round(self.bucket_frac, 4)),
                "pools": self.pools, "mode": self.mode,
                "alerts": self.alerts}


class FleetTimeSeries:
    """Ring-buffered per-tick fleet samples on the virtual clock."""

    def __init__(self, maxlen: int = 4096, interval_s: float = 0.0):
        self.maxlen = maxlen
        self.interval_s = interval_s
        self.samples: deque = deque(maxlen=maxlen)
        self.total_samples = 0           # including ones the ring aged out
        self._last_t = -float("inf")
        # per-pool decode counters at the last sample: the fleet rate is
        # differentiated per pool *before* summing, so a retired pool's
        # counters leaving telemetry (history compaction) can never make
        # the summed cumulative step backward and spike the rate negative
        self._pool_seen: Dict[str, int] = {}
        self._decode_cum = 0             # sanitized monotone cumulative

    # ------------------------------------------------------------------
    # write side (ServingClient.advance)
    # ------------------------------------------------------------------
    def observe(self, client, now: float) -> bool:
        """Take one sample of ``client`` at virtual time ``now``;
        returns False when decimated away by ``interval_s``."""
        if now - self._last_t < self.interval_s:
            return False
        self._last_t = now
        tel = client.router.telemetry
        queued = load = 0
        for p in client.router.pools.values():
            queued += p.queue_depth
            load += p.load
        current = {name: c.decode_tokens for name, c in tel.pools.items()}
        self._decode_cum += sum(
            max(0, v - self._pool_seen.get(name, 0))
            for name, v in current.items())
        self._pool_seen = current
        engines = client.engines
        occ = (sum(e.occupancy for e in engines.values()) / len(engines)
               if engines else 0.0)
        ctrl = client.controller
        self.samples.append(Sample(
            now, self._decode_cum, queued, load, occ,
            None if ctrl is None else ctrl.bucket.frac,
            len(client.router.pools),
            "nominal" if ctrl is None else ctrl.mode,
            tel.alerts.firing_count))
        self.total_samples += 1
        return True

    # ------------------------------------------------------------------
    # read side
    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.samples)

    def series(self, key: str) -> List:
        """One column over the retained window, e.g.
        ``series("queue_depth")`` or ``series("t")``."""
        return [getattr(s, key) for s in self.samples]

    def tokens_per_s(self) -> List[float]:
        """Decode-token rate between consecutive retained samples (the
        sanitized cumulative counter differentiates cleanly even when
        the ring decimated or aged out samples, and the clamp guarantees
        no negative rate survives whatever the counters did)."""
        out = []
        prev = None
        for s in self.samples:
            if prev is not None and s.t > prev.t:
                out.append(max(0.0, (s.decode_tokens - prev.decode_tokens)
                               / (s.t - prev.t)))
            elif prev is not None:
                out.append(0.0)
            prev = s
        return out

    def summary(self) -> Dict:
        """Compact roll-up for reports (the orbit ``report()`` embeds
        this): retained window, peaks, and terminal values."""
        if not self.samples:
            return {"samples": 0, "retained": 0}
        first, last = self.samples[0], self.samples[-1]
        rates = self.tokens_per_s()
        fracs = [s.bucket_frac for s in self.samples
                 if s.bucket_frac is not None]
        return {
            "samples": self.total_samples,
            "retained": len(self.samples),
            "t0": round(first.t, 6), "t1": round(last.t, 6),
            "queue_depth_peak": max(s.queue_depth for s in self.samples),
            "load_peak": max(s.load for s in self.samples),
            "occupancy_peak": round(max(s.occupancy
                                        for s in self.samples), 4),
            "tokens_per_s_peak": round(max(rates), 2) if rates else 0.0,
            "pools_min": min(s.pools for s in self.samples),
            "pools_max": max(s.pools for s in self.samples),
            "bucket_frac_min": (round(min(fracs), 4) if fracs else None),
            "bucket_frac_last": (round(fracs[-1], 4) if fracs else None),
            "mode_last": last.mode,
            "alerts_peak": max(s.alerts for s in self.samples),
        }

    def to_dict(self) -> Dict:
        return {"interval_s": self.interval_s, "maxlen": self.maxlen,
                "summary": self.summary(),
                "samples": [s.to_dict() for s in self.samples]}
