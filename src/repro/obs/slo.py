"""SLO engine: golden-signal SLIs, error budgets, burn-rate alerts.

PR 6 gave the fleet a flight recorder (spans, time-series) and PR 7 a
hardened data plane; this module adds the *judgment* layer — the piece
that turns raw measurements into "is the fleet meeting its objectives,
and if not, how fast is it burning the error budget?"

Three cooperating parts, all on the fleet's virtual clock:

* :class:`SLIRegistry` — golden-signal service-level indicators (TTFT,
  inter-token latency, queue wait, end-to-end latency, drop / reject /
  retry counts), per **fleet**, per **pool**, and per **SLO class**.
  There is no second instrumentation layer: the registry is fed from
  the same terminal paths that close span chains
  (``Telemetry.record_completion`` / ``record_drop`` /
  ``record_rejection`` — the exact sites that call
  ``Tracer.end_request``), and each signal lands in a
  reservoir-sampled :class:`~repro.router.telemetry.Histogram`.
* :class:`SLOSpec` / :class:`SLOObjective` — the objectives as data
  (JSON round-trip like ``FleetSpec``, unknown-key rejection,
  ``validate()``).  A latency objective like ``p99_ttft_s=0.1`` means
  "99% of requests see their first token within 100 ms"; the error
  budget is the allowed 1%.  ``availability=0.999`` budgets the
  fraction of requests that may be dropped, rejected, or violated.
* :class:`SLOEngine` — multi-window burn-rate evaluation (Google
  SRE-style): each objective keeps a timestamped good/bad event window;
  every tick the engine computes the burn rate (bad fraction over the
  window, divided by the budget) over a **fast** and a **slow** window.
  An alert fires when *both* windows are at or above the severity's
  threshold (``page_burn`` / ``warn_burn``) with at least
  ``min_events`` events in the fast window, and clears with hysteresis
  only when both burns fall below ``clear_frac`` x threshold — so a
  boundary-riding burn cannot flap the alert.

Alerts land on the :class:`AlertBus` that lives on ``Telemetry`` (so
``snapshot()["alerts"]`` always has a stable, zero-initialized shape)
with **stable reason codes** — ``p99_ttft_burn``, ``p99_itl_burn``,
``p99_e2e_burn``, ``availability_burn`` — and the orbit
``FleetController`` consumes them: a firing page alert floors the
dispatch mode at ``"conserve"``, joins the storm-ladder inputs, and any
firing alert suppresses autoscaler scale-down (never retire capacity
while the budget is burning).

Everything here is deterministic for a seeded run: events carry virtual
timestamps, windows are pruned on the virtual clock, and the histograms
use the seeded reservoir.
"""
from __future__ import annotations

from collections import deque
from dataclasses import asdict, dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.router.telemetry import Histogram

#: every alert reason code this module can emit (stable contract —
#: dashboards and the orbit controller match on these strings)
REASON_CODES = ("p99_ttft_burn", "p99_itl_burn", "p99_e2e_burn",
                "availability_burn")

#: latency signals an objective may bound (signal -> SLOObjective field)
_LATENCY_SIGNALS = {"p99_ttft": "p99_ttft_s", "p99_itl": "p99_itl_s",
                    "p99_e2e": "p99_e2e_s"}


# ---------------------------------------------------------------------------
# SLI registry: golden signals per fleet / pool / class
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SLIEvent:
    """One terminal-path observation, timestamped on the virtual clock.

    ``kind`` is one of ``"completion"`` / ``"drop"`` / ``"reject"`` /
    ``"retry"``; latency fields are None when the signal does not apply
    (e.g. ITL on a single-token or cost-model request)."""
    t: float
    kind: str
    slo_class: str
    pool: Optional[str] = None
    ttft_s: Optional[float] = None
    itl_s: Optional[float] = None
    queue_wait_s: Optional[float] = None
    e2e_s: Optional[float] = None
    violated: bool = False


class SLIScope:
    """Golden signals for one scope (the fleet, one pool, or one class)."""

    __slots__ = ("completed", "dropped", "rejected", "violated", "retries",
                 "ttft_s", "itl_s", "queue_wait_s", "e2e_s")

    def __init__(self):
        self.completed = 0
        self.dropped = 0
        self.rejected = 0
        self.violated = 0
        self.retries = 0
        self.ttft_s = Histogram()
        self.itl_s = Histogram()
        self.queue_wait_s = Histogram()
        self.e2e_s = Histogram()

    def summary(self) -> Dict:
        return {"completed": self.completed, "dropped": self.dropped,
                "rejected": self.rejected, "violated": self.violated,
                "retries": self.retries,
                "ttft_s": self.ttft_s.summary(),
                "itl_s": self.itl_s.summary(),
                "queue_wait_s": self.queue_wait_s.summary(),
                "e2e_s": self.e2e_s.summary()}


class SLIRegistry:
    """Always-on SLI accumulator, one per :class:`Telemetry`.

    Fed from the router/client terminal paths; fans each observation
    into the fleet scope, the request's SLO-class scope, and (when
    known) its pool scope, then notifies listeners (the
    :class:`SLOEngine` subscribes for burn-window events)."""

    def __init__(self):
        self.fleet = SLIScope()
        self.by_class: Dict[str, SLIScope] = {}
        self.by_pool: Dict[str, SLIScope] = {}
        self.listeners: List[Callable[[SLIEvent], None]] = []

    def _scopes(self, slo_class: str, pool: Optional[str]):
        yield self.fleet
        scope = self.by_class.get(slo_class)
        if scope is None:
            scope = self.by_class[slo_class] = SLIScope()
        yield scope
        if pool is not None:
            pscope = self.by_pool.get(pool)
            if pscope is None:
                pscope = self.by_pool[pool] = SLIScope()
            yield pscope

    def _emit(self, ev: SLIEvent) -> None:
        for fn in self.listeners:
            fn(ev)

    def observe_completion(self, t: float, slo_class: str,
                           pool: Optional[str], e2e_s: float,
                           ttft_s: Optional[float] = None,
                           itl_s: Optional[float] = None,
                           queue_wait_s: Optional[float] = None,
                           violated: bool = False) -> None:
        for s in self._scopes(slo_class, pool):
            s.completed += 1
            if violated:
                s.violated += 1
            s.e2e_s.record(e2e_s)
            if ttft_s is not None:
                s.ttft_s.record(ttft_s)
            if itl_s is not None:
                s.itl_s.record(itl_s)
            if queue_wait_s is not None:
                s.queue_wait_s.record(queue_wait_s)
        self._emit(SLIEvent(t, "completion", slo_class, pool, ttft_s,
                            itl_s, queue_wait_s, e2e_s, violated))

    def observe_drop(self, t: float, slo_class: str,
                     pool: Optional[str] = None) -> None:
        for s in self._scopes(slo_class, pool):
            s.dropped += 1
        self._emit(SLIEvent(t, "drop", slo_class, pool))

    def observe_reject(self, t: float, slo_class: str) -> None:
        for s in self._scopes(slo_class, None):
            s.rejected += 1
        self._emit(SLIEvent(t, "reject", slo_class))

    def observe_retry(self, t: float, slo_class: str,
                      pool: Optional[str] = None) -> None:
        for s in self._scopes(slo_class, pool):
            s.retries += 1
        self._emit(SLIEvent(t, "retry", slo_class, pool))

    def summary(self) -> Dict:
        return {"fleet": self.fleet.summary(),
                "by_class": {k: v.summary()
                             for k, v in sorted(self.by_class.items())},
                "by_pool": {k: v.summary()
                            for k, v in sorted(self.by_pool.items())}}


# ---------------------------------------------------------------------------
# alerts
# ---------------------------------------------------------------------------
@dataclass
class Alert:
    """One fired alert; ``t_cleared`` is None while it is still firing."""
    reason: str                    # stable code, one of REASON_CODES
    slo_class: str
    severity: str                  # "page" | "warn"
    t_fired: float
    burn_fast: float
    burn_slow: float
    threshold: float               # the burn multiple that fired it
    t_cleared: Optional[float] = None

    @property
    def key(self) -> str:
        return f"{self.reason}:{self.slo_class}:{self.severity}"

    def to_dict(self) -> Dict:
        return {"reason": self.reason, "slo_class": self.slo_class,
                "severity": self.severity,
                "t_fired": round(self.t_fired, 6),
                "burn_fast": round(self.burn_fast, 4),
                "burn_slow": round(self.burn_slow, 4),
                "threshold": self.threshold,
                "t_cleared": (None if self.t_cleared is None
                              else round(self.t_cleared, 6))}


class AlertBus:
    """Fleet alert state, one per :class:`Telemetry`.

    Zero-initialized so ``Telemetry.snapshot()["alerts"]`` has a stable
    shape whether or not an :class:`SLOEngine` is attached or anything
    ever fired.  ``history`` keeps the first ``max_history`` fired
    alerts (cleared ones get their ``t_cleared`` stamped in place)."""

    def __init__(self, max_history: int = 256):
        self.max_history = max_history
        self._firing: Dict[str, Alert] = {}
        self.history: List[Alert] = []
        self.pages_fired = 0               # cumulative, monotone
        self.warns_fired = 0
        self.cleared = 0

    def fire(self, alert: Alert) -> bool:
        """Raise ``alert``; returns False when its key already fires."""
        if alert.key in self._firing:
            return False
        self._firing[alert.key] = alert
        if len(self.history) < self.max_history:
            self.history.append(alert)
        if alert.severity == "page":
            self.pages_fired += 1
        else:
            self.warns_fired += 1
        return True

    def clear(self, key: str, now: float) -> bool:
        alert = self._firing.pop(key, None)
        if alert is None:
            return False
        alert.t_cleared = now
        self.cleared += 1
        return True

    def is_firing(self, key: str) -> bool:
        return key in self._firing

    @property
    def firing(self) -> List[Alert]:
        return list(self._firing.values())

    @property
    def firing_count(self) -> int:
        return len(self._firing)

    @property
    def paging(self) -> bool:
        """Any page-severity alert currently firing (the signal the
        orbit controller floors the mode on)."""
        return any(a.severity == "page" for a in self._firing.values())

    def snapshot(self) -> Dict:
        return {"firing": [a.to_dict() for a in self._firing.values()],
                "firing_count": len(self._firing),
                "pages_fired": self.pages_fired,
                "warns_fired": self.warns_fired,
                "cleared": self.cleared}


# ---------------------------------------------------------------------------
# objectives as data
# ---------------------------------------------------------------------------
@dataclass
class SLOObjective:
    """Per-class objectives.  Latency bounds are p99 targets (99% of
    requests must land at or under the bound); ``availability`` is the
    required fraction of requests not dropped / rejected / violated."""
    slo_class: str
    p99_ttft_s: Optional[float] = None
    p99_itl_s: Optional[float] = None
    p99_e2e_s: Optional[float] = None
    availability: Optional[float] = None

    def to_dict(self) -> Dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "SLOObjective":
        valid = set(cls.__dataclass_fields__)
        unknown = sorted(set(d) - valid)
        if unknown:
            raise ValueError(
                f"SLOObjective.from_dict: unknown key(s) {unknown}; "
                f"valid keys are {sorted(valid)}")
        return cls(**d)

    def expanded(self) -> List[Tuple[str, Optional[float], float]]:
        """Concrete (signal, threshold_s, good-fraction target) tuples,
        one per declared bound."""
        out: List[Tuple[str, Optional[float], float]] = []
        for signal, field in _LATENCY_SIGNALS.items():
            bound = getattr(self, field)
            if bound is not None:
                out.append((signal, bound, 0.99))
        if self.availability is not None:
            out.append(("availability", None, self.availability))
        return out


@dataclass
class SLOSpec:
    """The SLO plane as data; ``attach(client)`` makes it live.

    Burn-rate semantics (documented thresholds — the tests pin them):
    an alert of severity *s* (threshold ``page_burn`` or ``warn_burn``)
    **fires** the first tick where both the fast- and slow-window burn
    rates are >= the threshold and the fast window holds at least
    ``min_events`` events; it **clears** only when both burns fall
    below ``clear_frac * threshold`` (hysteresis — no flapping while
    the burn rides the threshold)."""
    objectives: List[SLOObjective]
    fast_window_s: float = 1.0
    slow_window_s: float = 5.0
    page_burn: float = 10.0
    warn_burn: float = 2.0
    clear_frac: float = 0.5
    min_events: int = 5

    # ------------------------------------------------------------------
    # serialization (JSON round-trip, like FleetSpec)
    # ------------------------------------------------------------------
    def to_dict(self) -> Dict:
        return {"objectives": [o.to_dict() for o in self.objectives],
                "fast_window_s": self.fast_window_s,
                "slow_window_s": self.slow_window_s,
                "page_burn": self.page_burn,
                "warn_burn": self.warn_burn,
                "clear_frac": self.clear_frac,
                "min_events": self.min_events}

    @classmethod
    def from_dict(cls, d: Dict) -> "SLOSpec":
        d = dict(d)
        valid = set(cls.__dataclass_fields__)
        unknown = sorted(set(d) - valid)
        if unknown:
            raise ValueError(
                f"SLOSpec.from_dict: unknown key(s) {unknown}; valid "
                f"keys are {sorted(valid)}")
        d["objectives"] = [SLOObjective.from_dict(o)
                           for o in d.get("objectives", [])]
        return cls(**d)

    # ------------------------------------------------------------------
    # validation
    # ------------------------------------------------------------------
    def validate(self) -> "SLOSpec":
        """Fail fast before the engine goes live (called by
        ``attach()``)."""
        if not self.objectives:
            raise ValueError("SLOSpec needs at least one SLOObjective")
        seen = set()
        for o in self.objectives:
            if o.slo_class in seen:
                raise ValueError(f"duplicate objective for SLO class "
                                 f"{o.slo_class!r}")
            seen.add(o.slo_class)
            if not o.expanded():
                raise ValueError(f"objective for {o.slo_class!r} declares "
                                 f"no bound (set p99_*_s or availability)")
            for field in _LATENCY_SIGNALS.values():
                bound = getattr(o, field)
                if bound is not None and bound <= 0:
                    raise ValueError(f"{o.slo_class!r}.{field} must be "
                                     f"> 0 (got {bound})")
            if o.availability is not None \
                    and not 0.0 < o.availability < 1.0:
                raise ValueError(f"{o.slo_class!r}.availability must be "
                                 f"in (0, 1) (got {o.availability}) — "
                                 f"1.0 leaves a zero error budget")
        if not 0.0 < self.fast_window_s < self.slow_window_s:
            raise ValueError(
                f"need 0 < fast_window_s < slow_window_s, got "
                f"{self.fast_window_s} / {self.slow_window_s}")
        if not 0.0 < self.warn_burn <= self.page_burn:
            raise ValueError(f"need 0 < warn_burn <= page_burn, got "
                             f"{self.warn_burn} / {self.page_burn}")
        if not 0.0 < self.clear_frac <= 1.0:
            raise ValueError(f"clear_frac must be in (0, 1] "
                             f"(got {self.clear_frac})")
        if self.min_events < 1:
            raise ValueError(f"min_events must be >= 1 "
                             f"(got {self.min_events})")
        return self

    # ------------------------------------------------------------------
    # assembly
    # ------------------------------------------------------------------
    def attach(self, client) -> "SLOEngine":
        """Build the live engine onto a ServingClient (one per client);
        ``ServingClient.advance`` steps it every tick."""
        self.validate()
        if getattr(client, "slo_engine", None) is not None:
            raise ValueError("an SLO engine is already attached")
        engine = SLOEngine(client, self)
        client.attach_slo(engine)
        return engine


# ---------------------------------------------------------------------------
# burn-rate evaluation
# ---------------------------------------------------------------------------
class _Tracker:
    """Multi-window burn state for one (class, signal) objective."""

    def __init__(self, slo_class: str, signal: str,
                 threshold: Optional[float], target: float, spec: SLOSpec):
        self.slo_class = slo_class
        self.signal = signal              # "p99_ttft" | ... | "availability"
        self.threshold = threshold        # latency bound; None for avail
        self.target = target              # required good-event fraction
        self.budget = max(1.0 - target, 1e-9)
        self.spec = spec
        # two event windows with incremental bad-counts: ``burn()`` runs
        # every fleet tick, so it must amortize O(1) per event, never
        # rescan the windows
        self.events: deque = deque()      # (t, good) within slow window
        self._fast: deque = deque()       # (t, good) within fast window
        self._bad_slow = 0
        self._bad_fast = 0
        self.total = 0                    # cumulative events (monotone)
        self.bad = 0                      # cumulative bad events (monotone)
        self.reason = f"{signal}_burn"

    def _judge(self, ev: SLIEvent) -> Optional[bool]:
        """Good / bad / not-applicable (None) for this objective."""
        if ev.slo_class != self.slo_class:
            return None
        if self.signal == "availability":
            if ev.kind == "completion":
                return not ev.violated
            if ev.kind in ("drop", "reject"):
                return False
            return None
        if ev.kind == "drop":
            # a dropped request never delivered its first token at all:
            # the worst possible latency outcome, so it burns budget
            return False
        if ev.kind != "completion":
            return None
        value = {"p99_ttft": ev.ttft_s, "p99_itl": ev.itl_s,
                 "p99_e2e": ev.e2e_s}[self.signal]
        if value is None:
            return None                   # signal not measurable here
        return value <= self.threshold

    def observe(self, ev: SLIEvent) -> None:
        good = self._judge(ev)
        if good is None:
            return
        self.events.append((ev.t, good))
        self._fast.append((ev.t, good))
        self.total += 1
        if not good:
            self.bad += 1
            self._bad_slow += 1
            self._bad_fast += 1

    def burn(self, now: float) -> Tuple[float, float, int, int]:
        """(burn_fast, burn_slow, n_fast, n_slow) at virtual ``now``:
        bad-event fraction over each window divided by the budget."""
        horizon = now - self.spec.slow_window_s
        ev = self.events
        while ev and ev[0][0] < horizon:
            if not ev.popleft()[1]:
                self._bad_slow -= 1
        t_fast = now - self.spec.fast_window_s
        fv = self._fast
        while fv and fv[0][0] < t_fast:
            if not fv.popleft()[1]:
                self._bad_fast -= 1
        n_slow, n_fast = len(ev), len(fv)
        burn_fast = self._bad_fast / n_fast / self.budget if n_fast else 0.0
        burn_slow = self._bad_slow / n_slow / self.budget if n_slow else 0.0
        return burn_fast, burn_slow, n_fast, n_slow

    def budget_remaining(self) -> float:
        """Fraction of the cumulative error budget left, in [0, 1]:
        the budget allows ``budget x total`` bad events; consumption
        (``bad``) is monotone."""
        if not self.total:
            return 1.0
        return max(0.0, 1.0 - self.bad / (self.budget * self.total))


class SLOEngine:
    """Live burn-rate evaluator over one client's SLI stream.

    Subscribes to the telemetry's :class:`SLIRegistry` (so completions,
    drops, rejections, and retries flow in from the terminal paths with
    no extra instrumentation) and drives the telemetry's
    :class:`AlertBus` from ``step(now)`` — called by
    ``ServingClient.advance`` every tick, *before* the orbit controller
    steps, so control decisions see this tick's alert state."""

    def __init__(self, client, spec: SLOSpec):
        self.client = client
        self.spec = spec
        tel = client.router.telemetry
        self.slis: SLIRegistry = tel.slis
        self.bus: AlertBus = tel.alerts
        self.trackers: List[_Tracker] = []
        for obj in spec.objectives:
            for signal, threshold, target in obj.expanded():
                self.trackers.append(
                    _Tracker(obj.slo_class, signal, threshold, target,
                             spec))
        self.slis.listeners.append(self._observe)
        # step() runs every fleet tick: precompute each tracker's alert
        # keys and thresholds so the hot loop allocates nothing
        self._eval = [
            (tr, (("page", spec.page_burn,
                   f"{tr.reason}:{tr.slo_class}:page"),
                  ("warn", spec.warn_burn,
                   f"{tr.reason}:{tr.slo_class}:warn")))
            for tr in self.trackers]
        # per-tick ring for Chrome-trace counter tracks: (t, worst fast
        # burn, firing alerts, min budget remaining)
        self.history: deque = deque(maxlen=4096)

    def _observe(self, ev: SLIEvent) -> None:
        for tr in self.trackers:
            tr.observe(ev)

    def step(self, now: float) -> None:
        worst_burn = 0.0
        budget_min = 1.0
        for tr, severities in self._eval:
            burn_fast, burn_slow, n_fast, _ = tr.burn(now)
            worst_burn = max(worst_burn, burn_fast)
            budget_min = min(budget_min, tr.budget_remaining())
            for severity, thr, key in severities:
                if self.bus.is_firing(key):
                    clear_at = thr * self.spec.clear_frac
                    if burn_fast < clear_at and burn_slow < clear_at:
                        self.bus.clear(key, now)
                elif (n_fast >= self.spec.min_events
                        and burn_fast >= thr and burn_slow >= thr):
                    self.bus.fire(Alert(tr.reason, tr.slo_class, severity,
                                        now, burn_fast, burn_slow, thr))
        self.history.append((now, worst_burn, self.bus.firing_count,
                             budget_min))

    # ------------------------------------------------------------------
    # read side
    # ------------------------------------------------------------------
    def objectives(self, now: Optional[float] = None) -> List[Dict]:
        """Per-objective evaluation state (burns, budget, alert flags)."""
        now = self.client.now if now is None else now
        out = []
        for tr in self.trackers:
            burn_fast, burn_slow, _, _ = tr.burn(now)
            out.append({
                "slo_class": tr.slo_class,
                "objective": tr.signal,
                "threshold_s": tr.threshold,
                "target": tr.target,
                "events": tr.total,
                "bad_events": tr.bad,
                "budget_remaining": round(tr.budget_remaining(), 6),
                "burn_fast": round(burn_fast, 4),
                "burn_slow": round(burn_slow, 4),
                "page": self.bus.is_firing(
                    f"{tr.reason}:{tr.slo_class}:page"),
                "warn": self.bus.is_firing(
                    f"{tr.reason}:{tr.slo_class}:warn"),
            })
        return out

    def report(self) -> Dict:
        """The full SLO judgment (what ``SLO_report.json`` serializes)."""
        return {"spec": self.spec.to_dict(),
                "objectives": self.objectives(),
                "slis": self.slis.summary(),
                "alerts": self.bus.snapshot(),
                "alert_history": [a.to_dict() for a in self.bus.history]}
