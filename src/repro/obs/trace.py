"""Flight-recorder span tracing: one span tree per request.

The :class:`Tracer` is the fleet's black box.  Every request that enters
:meth:`~repro.serving.client.ServingClient.submit` opens a root
``request`` span; the data and control plane close the chain around it::

    submit -> queue -> [admit | prefill_chunk*] -> [handoff -> import]
           -> serve (one per routed batch) -> complete | reject | drop
    submit -> defer -> queue -> ...            (orbit energy deferral)

Spans live on the fleet's *virtual* clock (the same clock telemetry,
the orbit bucket, and the traffic driver share), so a seeded run
produces a bit-identical trace on any machine; engine-internal detail
(per-chunk prefill, per-step decode batches) is measured in wall time
and anchored at the virtual instant its routed batch launched, so the
two timelines nest coherently in one view.

Design constraints, in order:

1. **Zero overhead off.**  ``enabled`` is False by default and every
   recording method returns immediately; the engines' ``on_stage`` hook
   is only installed while a traced batch runs.
2. **No orphan spans.**  Every terminal event (completion, rejection,
   drop, eviction) closes the request's open spans through
   :meth:`end_request`; ``open_spans()`` after a drained run is the
   test-enforced invariant.
3. **Bounded memory.**  ``max_spans`` caps the record; further spans
   are counted in ``dropped`` rather than silently discarded, and
   already-open spans still close so invariant 2 survives the cap.

One tracer per fleet: it lives on
:class:`~repro.router.telemetry.Telemetry` (the shared observability
bag every layer already holds), and
``ResponseHandle.trace()`` / :func:`repro.obs.export` read it back out.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

#: Terminal outcomes a request chain can close with.
OUTCOMES = ("completed", "rejected", "energy_rejected", "dropped")


@dataclass
class Span:
    """One timed stage of one request (or a fleet-lane event)."""
    sid: int
    rid: Optional[int]                 # None -> fleet/pool lane span
    stage: str
    t0: float
    t1: Optional[float] = None         # None while open
    pool: Optional[str] = None
    attrs: Dict = field(default_factory=dict)

    @property
    def open(self) -> bool:
        return self.t1 is None

    @property
    def duration_s(self) -> float:
        return 0.0 if self.t1 is None else self.t1 - self.t0

    def to_dict(self) -> Dict:
        return {"sid": self.sid, "rid": self.rid, "stage": self.stage,
                "t0": round(self.t0, 9),
                "t1": None if self.t1 is None else round(self.t1, 9),
                "pool": self.pool, "attrs": dict(self.attrs)}


class Tracer:
    """Per-request span recorder over the fleet's virtual clock.

    Disabled by default: every method is a cheap no-op until
    ``enabled`` flips True (``ServingClient.enable_tracing()``), so the
    serving hot path pays one attribute check per instrumentation
    point.
    """

    def __init__(self, enabled: bool = False, max_spans: int = 200_000):
        self.enabled = enabled
        self.max_spans = max_spans
        self.spans: List[Span] = []
        self.dropped = 0               # spans lost to the max_spans cap
        self.outcomes: Dict[int, str] = {}
        self._by_rid: Dict[int, List[Span]] = {}
        self._open: Dict[int, Dict[str, Span]] = {}   # rid -> stage -> span
        self._next_sid = 0

    # ------------------------------------------------------------------
    # recording
    # ------------------------------------------------------------------
    def _new(self, rid: Optional[int], stage: str, t0: float,
             t1: Optional[float], pool: Optional[str],
             attrs: Dict) -> Optional[Span]:
        if len(self.spans) >= self.max_spans:
            self.dropped += 1
            return None
        sp = Span(self._next_sid, rid, stage, t0, t1, pool, attrs)
        self._next_sid += 1
        self.spans.append(sp)
        if rid is not None:
            self._by_rid.setdefault(rid, []).append(sp)
        return sp

    def begin_request(self, rid: int, t: float, **attrs) -> None:
        """Open the root ``request`` span (the chain's anchor)."""
        if not self.enabled:
            return
        self.begin(rid, "request", t, **attrs)

    def begin(self, rid: int, stage: str, t: float,
              pool: Optional[str] = None, **attrs) -> None:
        """Open one stage span for ``rid``.  At most one span per
        (rid, stage) is open at a time; a stale open one (e.g. a queue
        span whose pool was destroyed without an eviction event) is
        closed defensively at ``t`` so chains can never leak."""
        if not self.enabled:
            return
        open_stages = self._open.setdefault(rid, {})
        stale = open_stages.pop(stage, None)
        if stale is not None:
            stale.t1 = t
            stale.attrs.setdefault("truncated", True)
        sp = self._new(rid, stage, t, None, pool, attrs)
        if sp is not None:
            open_stages[stage] = sp

    def finish(self, rid: int, stage: str, t: float, **attrs) -> None:
        """Close the open (rid, stage) span; no-op when none is open."""
        if not self.enabled:
            return
        sp = self._open.get(rid, {}).pop(stage, None)
        if sp is not None:
            sp.t1 = t
            sp.attrs.update(attrs)

    def add(self, rid: Optional[int], stage: str, t0: float, t1: float,
            pool: Optional[str] = None, **attrs) -> None:
        """Record an already-closed span (both endpoints known)."""
        if not self.enabled:
            return
        self._new(rid, stage, t0, t1, pool, attrs)

    def event(self, stage: str, t: float, rid: Optional[int] = None,
              pool: Optional[str] = None, **attrs) -> None:
        """Record an instant marker (duration-0 span)."""
        if not self.enabled:
            return
        self._new(rid, stage, t, t, pool, attrs)

    def end_request(self, rid: int, t: float, outcome: str,
                    **attrs) -> None:
        """Terminal event: record ``outcome`` and close the whole chain
        — the root span and anything still open — at ``t``.  Every exit
        path (completion, rejection, drop) funnels through here, which
        is what makes "no orphan spans" enforceable."""
        if not self.enabled:
            return
        open_stages = self._open.pop(rid, {})
        root = open_stages.pop("request", None)
        for sp in open_stages.values():       # e.g. queue span of a drop
            sp.t1 = t
            sp.attrs.setdefault("truncated", True)
        if root is not None:
            root.t1 = t
            root.attrs.update(attrs)
            root.attrs["outcome"] = outcome
        self.outcomes[rid] = outcome

    # ------------------------------------------------------------------
    # read-back
    # ------------------------------------------------------------------
    @property
    def request_ids(self) -> List[int]:
        return sorted(self._by_rid)

    def spans_for(self, rid: int) -> List[Span]:
        return list(self._by_rid.get(rid, []))

    def open_spans(self) -> List[Span]:
        """Spans still open — empty after a drained run (the orphan
        invariant the test suite locks in)."""
        return [sp for stages in self._open.values()
                for sp in stages.values()]

    def closed(self, rid: int) -> bool:
        """Is this request's chain fully closed (terminal outcome seen,
        no open spans)?"""
        return rid in self.outcomes and not self._open.get(rid)

    def trace(self, rid: int) -> Optional[Dict]:
        """The request's span tree: the root ``request`` span with every
        other span nested under the innermost span whose interval
        contains it (prefill chunks nest under their serve span, etc.).
        Returns None when the rid was never traced."""
        spans = self._by_rid.get(rid)
        if not spans:
            return None
        root = next((s for s in spans if s.stage == "request"), spans[0])
        nodes = {s.sid: {**s.to_dict(), "children": []} for s in spans}
        rest = sorted((s for s in spans if s.sid != root.sid),
                      key=lambda s: (s.t0, -(s.t1 if s.t1 is not None
                                             else s.t0)))
        stack = [root]

        def _end(s: Span) -> float:
            return s.t1 if s.t1 is not None else float("inf")

        for s in rest:
            while len(stack) > 1 and not (stack[-1].t0 <= s.t0
                                          and _end(s) <= _end(stack[-1])):
                stack.pop()
            nodes[stack[-1].sid]["children"].append(nodes[s.sid])
            stack.append(s)
        out = nodes[root.sid]
        out["outcome"] = self.outcomes.get(rid)
        return out

    # ------------------------------------------------------------------
    # serialization
    # ------------------------------------------------------------------
    def to_jsonl(self, path) -> int:
        """One span per line (creation order); returns the line count."""
        import json
        with open(path, "w") as f:
            for sp in self.spans:
                f.write(json.dumps(sp.to_dict()) + "\n")
        return len(self.spans)

    def summary(self) -> Dict:
        return {"spans": len(self.spans), "dropped": self.dropped,
                "requests": len(self._by_rid),
                "open": len(self.open_spans()),
                "outcomes": {o: sum(1 for v in self.outcomes.values()
                                    if v == o)
                             for o in sorted(set(self.outcomes.values()))}}
