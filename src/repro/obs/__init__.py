"""repro.obs — the flight recorder and SLO plane over the serving stack.

Five layers, one subsystem:

* :mod:`repro.obs.trace` — per-request span trees on the fleet's
  virtual clock (``submit -> queue -> admit/prefill -> handoff ->
  serve/decode -> complete|reject|drop``), recorded by hooks threaded
  through ``ServingClient``, ``Router``, ``AcceleratorPool``,
  ``EngineExecutor``, the engines, and the orbit ``FleetController``.
  Read one back with ``ResponseHandle.trace()``.
* :mod:`repro.obs.timeseries` — a bounded ring buffer of per-tick
  fleet samples (tokens/s, queue depth, occupancy, bucket level, pool
  count, mode, firing alerts), replacing the final-snapshot-only view;
  the orbit report embeds its summary.
* :mod:`repro.obs.slo` — golden-signal SLIs (TTFT, inter-token latency,
  queue wait, e2e latency, drop/retry rates; per pool and per SLO
  class), declarative :class:`SLOSpec` objectives with error budgets,
  multi-window burn-rate alerting, and the :class:`AlertBus` the orbit
  controller consumes.
* :mod:`repro.obs.metrics` — Prometheus text-format dump and the
  ``SLO_report.json`` judgment artifact.
* :mod:`repro.obs.export` — spans to JSONL and to Chrome
  ``trace_event`` JSON (one lane per pool/stage, orbit phases as async
  spans, SLI/alert counter tracks), viewable in Perfetto.

Quickstart::

    from repro.obs import SLOObjective, SLOSpec
    spec = FleetSpec(..., slo=SLOSpec(objectives=[
        SLOObjective("realtime-tracking", p99_ttft_s=0.1,
                     availability=0.999)]))
    client = spec.build()                   # engine attached + stepping
    ...
    client.telemetry["alerts"]              # firing burn alerts
    from repro.obs import export_slo_report
    export_slo_report(client, "SLO_report.json")

See ``src/repro/obs/README.md`` for the full tour (reason codes,
``python -m repro.launch.top``, benchstat).
"""
from repro.obs.export import (chrome_trace, export_chrome_trace,
                              export_spans_jsonl)
from repro.obs.metrics import (export_prometheus, export_slo_report,
                               prometheus_text, slo_report)
from repro.obs.slo import (REASON_CODES, Alert, AlertBus, SLIRegistry,
                           SLIScope, SLOEngine, SLOObjective, SLOSpec)
from repro.obs.timeseries import FleetTimeSeries, Sample
from repro.obs.trace import OUTCOMES, Span, Tracer

__all__ = ["Alert", "AlertBus", "FleetTimeSeries", "OUTCOMES",
           "REASON_CODES", "SLIRegistry", "SLIScope", "SLOEngine",
           "SLOObjective", "SLOSpec", "Sample", "Span", "Tracer",
           "chrome_trace", "export_chrome_trace", "export_prometheus",
           "export_slo_report", "export_spans_jsonl", "prometheus_text",
           "slo_report"]
