"""repro.obs — the flight recorder over the serving stack.

Three layers, one subsystem:

* :mod:`repro.obs.trace` — per-request span trees on the fleet's
  virtual clock (``submit -> queue -> admit/prefill -> handoff ->
  serve/decode -> complete|reject|drop``), recorded by hooks threaded
  through ``ServingClient``, ``Router``, ``AcceleratorPool``,
  ``EngineExecutor``, the engines, and the orbit ``FleetController``.
  Read one back with ``ResponseHandle.trace()``.
* :mod:`repro.obs.timeseries` — a bounded ring buffer of per-tick
  fleet samples (tokens/s, queue depth, occupancy, bucket level, pool
  count, mode), replacing the final-snapshot-only view; the orbit
  report embeds its summary.
* :mod:`repro.obs.export` — spans to JSONL and to Chrome
  ``trace_event`` JSON (one lane per pool/stage, orbit phases as async
  spans), viewable in Perfetto.

Quickstart::

    client = spec.build()                   # or FleetSpec(..., trace=True)
    client.enable_tracing()
    h = client.submit(prompt, max_new=8)
    h.result()
    print(h.trace())                        # the span tree
    from repro.obs import export_chrome_trace
    export_chrome_trace(client, "trace.json")   # open in Perfetto
"""
from repro.obs.export import (chrome_trace, export_chrome_trace,
                              export_spans_jsonl)
from repro.obs.timeseries import FleetTimeSeries, Sample
from repro.obs.trace import OUTCOMES, Span, Tracer

__all__ = ["FleetTimeSeries", "OUTCOMES", "Sample", "Span", "Tracer",
           "chrome_trace", "export_chrome_trace", "export_spans_jsonl"]
